//! The STaMP pipeline: sequence transform + mixed-precision quantization,
//! packaged as (a) a standalone activation quantizer and (b) a quantized
//! linear-layer operator implementing the pseudocode of Figure 2a:
//!
//! ```text
//! Y = L⁻¹( Q(L X R) · (R⁻¹ W) ) + 1βᵀ
//! ```
//!
//! The inverse sequence transform commutes past the (quantized) matmul
//! (Eq. 7), and the feature transform's inverse is fused into the weight,
//! so at runtime the only extra work is `L`, `Q`, and `L⁻¹` — both `L`s
//! O(sd) for the Haar DWT.

use crate::quant::{BitAllocation, Granularity, QTensor, QuantScheme, Quantizer};
use crate::tensor::{qgemm, Tensor};
use crate::transforms::{
    DctTransform, FeatureTransform, HaarDwt, HaarDwt2d, IdentitySeq, KltTransform,
    SequenceTransform, WhtTransform,
};

/// Which sequence transform to instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqTransformKind {
    Identity,
    /// 1-D Haar DWT with `levels` analysis steps (the paper's default: 3).
    HaarDwt,
    /// 2-D Haar DWT over an `h×w` token grid (LVM latents).
    HaarDwt2d { h: usize, w: usize },
    Dct,
    Wht,
}

impl SeqTransformKind {
    pub fn label(&self) -> &'static str {
        match self {
            SeqTransformKind::Identity => "identity",
            SeqTransformKind::HaarDwt => "dwt",
            SeqTransformKind::HaarDwt2d { .. } => "dwt2d",
            SeqTransformKind::Dct => "dct",
            SeqTransformKind::Wht => "wht",
        }
    }
}

/// Configuration for a STaMP activation quantizer.
///
/// The default is the paper's main setting (3-level Haar DWT, 64 tokens at
/// 8 bits, the rest at 4, per-token scales). Typical usage — build a
/// [`Stamp`] for a sequence length and quantize activations:
///
/// ```
/// use stamp::stamp::{SeqTransformKind, Stamp, StampConfig};
/// use stamp::tensor::Tensor;
///
/// let cfg = StampConfig {
///     transform: SeqTransformKind::HaarDwt,
///     hp_tokens: 16, // leading coefficients kept at hp_bits
///     hp_bits: 8,
///     lp_bits: 4,
///     ..Default::default()
/// };
/// let stamp = Stamp::new(cfg, 256);
///
/// // Average storage cost interpolates between lp and hp bits.
/// let avg = stamp.average_bits(64);
/// assert!(avg > 4.0 && avg < 5.0, "avg bits {avg}");
///
/// // Quantize-dequantize is shape-preserving and finite.
/// let x = Tensor::randn(&[256, 64], 1);
/// let q = stamp.quantize_dequantize(&x);
/// assert_eq!(q.shape(), x.shape());
/// assert!(q.all_finite());
/// ```
#[derive(Clone, Debug)]
pub struct StampConfig {
    pub transform: SeqTransformKind,
    /// DWT levels (ignored by other transforms). Paper uses 3.
    pub levels: usize,
    /// Number of leading (high-energy) coefficients kept at `hp_bits`.
    pub hp_tokens: usize,
    pub hp_bits: u32,
    pub lp_bits: u32,
    pub granularity: Granularity,
    /// LLM attention-sink handling (paper §B.2): keep token 0 out of the
    /// transform so its massive outliers stay representable at 8 bits.
    pub skip_first_token: bool,
}

impl Default for StampConfig {
    fn default() -> Self {
        StampConfig {
            transform: SeqTransformKind::HaarDwt,
            levels: 3,
            hp_tokens: 64,
            hp_bits: 8,
            lp_bits: 4,
            granularity: Granularity::PerToken,
            skip_first_token: false,
        }
    }
}

impl StampConfig {
    /// Construct the sequence transform for sequence length `s` (after any
    /// first-token exclusion).
    fn build_transform(&self, s: usize) -> Box<dyn SequenceTransform> {
        match self.transform {
            SeqTransformKind::Identity => Box::new(IdentitySeq::new(s)),
            SeqTransformKind::HaarDwt => {
                let max = HaarDwt::max_levels(s);
                Box::new(HaarDwt::new(s, self.levels.min(max).max(1)))
            }
            SeqTransformKind::HaarDwt2d { h, w } => {
                assert_eq!(h * w, s, "2-D grid {h}x{w} != sequence length {s}");
                let max = HaarDwt::max_levels(h.min(w));
                Box::new(HaarDwt2d::new(h, w, self.levels.min(max).max(1)))
            }
            SeqTransformKind::Dct => Box::new(DctTransform::new(s)),
            SeqTransformKind::Wht => Box::new(WhtTransform::new(s)),
        }
    }
}

/// A STaMP activation quantizer bound to a fixed sequence length.
///
/// Sequence lengths that don't fit the transform (odd lengths after the
/// attention-sink exclusion, non-power-of-two for WHT) are zero-padded up
/// to the next valid length; Haar mixes a trailing sample with a zero row
/// into an `(x/√2, x/√2)` pair, so padding preserves energy and perfect
/// reconstruction (the paper picks Haar for exactly this "minimal padding"
/// property, §3.2 fn. 2).
pub struct Stamp {
    cfg: StampConfig,
    transform: Box<dyn SequenceTransform>,
    quantizer: Quantizer,
    /// Full sequence length including a skipped first token.
    s_total: usize,
    /// Effective (pre-padding) transformed length.
    s_eff: usize,
    /// Zero rows appended before the transform.
    pad: usize,
}

impl Stamp {
    pub fn new(cfg: StampConfig, s: usize) -> Self {
        let s_eff = if cfg.skip_first_token { s - 1 } else { s };
        // Padding requirements per transform.
        let s_pad = match cfg.transform {
            SeqTransformKind::HaarDwt => {
                let levels = cfg.levels.min(HaarDwt::max_levels(s_eff.next_power_of_two())).max(1);
                let m = 1usize << levels;
                s_eff.div_ceil(m) * m
            }
            SeqTransformKind::Wht => s_eff.next_power_of_two(),
            _ => s_eff,
        };
        let transform = cfg.build_transform(s_pad);
        let scheme = QuantScheme {
            granularity: cfg.granularity,
            bits: BitAllocation::two_level(cfg.hp_tokens.min(s_pad), cfg.hp_bits, cfg.lp_bits),
        };
        let quantizer = Quantizer::new(scheme, s_pad);
        Stamp { cfg, transform, quantizer, s_total: s, s_eff, pad: s_pad - s_eff }
    }

    /// Append the zero padding rows.
    fn pad_rows(&self, x: &Tensor) -> Tensor {
        if self.pad == 0 {
            x.clone()
        } else {
            x.vcat(&Tensor::zeros(&[self.pad, x.cols()]))
        }
    }

    /// Build a KLT-based STaMP from calibration samples (optimality
    /// reference; not a `SeqTransformKind` because it needs data).
    pub fn with_klt(cfg: StampConfig, samples: &[Tensor]) -> Self {
        assert!(!cfg.skip_first_token, "KLT path does not implement sink exclusion");
        let s = samples[0].rows();
        let transform: Box<dyn SequenceTransform> = Box::new(KltTransform::calibrate(samples));
        let scheme = QuantScheme {
            granularity: cfg.granularity,
            bits: BitAllocation::two_level(cfg.hp_tokens.min(s), cfg.hp_bits, cfg.lp_bits),
        };
        let quantizer = Quantizer::new(scheme, s);
        Stamp { cfg, transform, quantizer, s_total: s, s_eff: s, pad: 0 }
    }

    pub fn config(&self) -> &StampConfig {
        &self.cfg
    }

    pub fn transform(&self) -> &dyn SequenceTransform {
        self.transform.as_ref()
    }

    /// Average activation bits/element (incl. scale overhead) — the number
    /// reported in the tables (4.0625 / 4.125 in the paper). Padding rows
    /// are excluded: a real kernel never materializes them.
    pub fn average_bits(&self, d: usize) -> f64 {
        let mut avg = self.quantizer.scheme().average_bits(self.s_eff, d);
        if self.cfg.skip_first_token {
            // First token is always hp_bits.
            avg = (avg * self.s_eff as f64 + self.cfg.hp_bits as f64) / self.s_total as f64;
        }
        avg
    }

    /// Quantize-dequantize activations: `L⁻¹ Q(L X)`.
    pub fn quantize_dequantize(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rows(), self.s_total);
        if self.cfg.skip_first_token {
            let first = x.slice_rows(0, 1);
            let rest = x.slice_rows(1, self.s_total);
            // First token: plain hp-bit per-token quantization.
            let qfirst = QuantScheme::uniform(self.cfg.hp_bits, self.cfg.granularity).apply(&first);
            let lx = self.transform.forward(&self.pad_rows(&rest));
            let q = self.quantizer.apply(&lx);
            qfirst.vcat(&self.transform.inverse(&q).slice_rows(0, self.s_eff))
        } else {
            let lx = self.transform.forward(&self.pad_rows(x));
            let q = self.quantizer.apply(&lx);
            self.transform.inverse(&q).slice_rows(0, self.s_eff)
        }
    }

    /// Transformed-domain QDQ without the inverse — what a fused
    /// STaMP-linear kernel consumes (the inverse is applied after the
    /// matmul via [`Stamp::inverse_trim`], see [`StampLinear`]).
    pub fn quantize_transformed(&self, x: &Tensor) -> Tensor {
        assert!(!self.cfg.skip_first_token, "fused path handles sink in StampLinear");
        let lx = self.transform.forward(&self.pad_rows(x));
        self.quantizer.apply(&lx)
    }

    /// Packed counterpart of [`Stamp::quantize_transformed`]: the
    /// bit-packed integer codes `Q_int(L X)`, ready for
    /// [`crate::tensor::qgemm`]. Requires [`Stamp::packable`] bit widths.
    pub fn quantize_transformed_packed(&self, x: &Tensor) -> QTensor {
        assert!(!self.cfg.skip_first_token, "packed path does not implement sink exclusion");
        let lx = self.transform.forward(&self.pad_rows(x));
        self.quantizer.quantize(&lx)
    }

    /// Whether the configured bit widths pack into u8 lanes (4/8 bits) —
    /// the precondition for the packed integer path.
    pub fn packable(&self) -> bool {
        self.quantizer.packable()
    }

    /// Apply `L⁻¹` and drop padding rows (the post-matmul step of Eq. 7).
    pub fn inverse_trim(&self, y: &Tensor) -> Tensor {
        self.transform.inverse(y).slice_rows(0, self.s_eff)
    }

    /// FLOP overhead of the two transform applications around one linear
    /// layer (Table 3 accounting).
    pub fn transform_flops(&self, d: usize) -> u64 {
        2 * self.transform.flops(d)
    }
}

/// A STaMP-quantized linear layer `X ↦ X W + β` (Figure 2a).
///
/// Owns the (optionally feature-transform-fused) weight and executes
/// `L⁻¹(Q(L X R) W_fused) + 1βᵀ`, postponing the sequence inverse until
/// after the matmul (Eq. 7). With [`StampLinear::with_packed_weight`] the
/// middle product runs on the packed integer path: `L X R` is quantized
/// *once* into a [`QTensor`], multiplied against the pre-quantized packed
/// weight by [`crate::tensor::qgemm`], and only then inverse-transformed.
pub struct StampLinear {
    stamp: Stamp,
    /// Weight stored `[in, out]`, with `R⁻¹` already fused.
    weight: Tensor,
    bias: Option<Vec<f32>>,
    feature: Box<dyn FeatureTransform>,
    /// Pre-quantized packed weight (`[out, in]`); `Some` switches
    /// [`StampLinear::forward`] onto the integer fast path.
    qweight: Option<QTensor>,
}

impl StampLinear {
    pub fn new(
        stamp: Stamp,
        weight: Tensor,
        bias: Option<Vec<f32>>,
        feature: Box<dyn FeatureTransform>,
    ) -> Self {
        assert_eq!(weight.rows(), feature.dim(), "weight in-dim vs feature transform");
        let fused = feature.fuse_into_weight(&weight);
        StampLinear { stamp, weight: fused, bias, feature, qweight: None }
    }

    /// Pre-quantize the fused weight at `bits` (4/8) with optional
    /// per-block grouping along the input dimension (`None` =
    /// per-output-channel), and route subsequent forwards through the
    /// packed integer path. Mirrors the settings of
    /// [`crate::baselines::WeightQuantCfg`] without depending on it, so
    /// the L2 stamp layer stays upstream of the baselines stacks.
    pub fn with_packed_weight(mut self, bits: u32, block: Option<usize>) -> Self {
        assert!(bits == 4 || bits == 8, "packed weights need 4- or 8-bit lanes, got {bits}-bit");
        assert!(self.stamp.packable(), "packed path needs 4/8-bit activation lanes");
        assert!(
            !self.stamp.config().skip_first_token,
            "packed path does not implement sink exclusion"
        );
        self.qweight = Some(QTensor::from_weight(&self.weight, bits, block));
        self
    }

    /// The packed weight, when the integer path is enabled.
    pub fn packed_weight(&self) -> Option<&QTensor> {
        self.qweight.as_ref()
    }

    /// Plain un-quantized reference forward (for SQNR baselines).
    pub fn forward_fp(&self, x: &Tensor, original_weight: &Tensor) -> Tensor {
        let mut y = x.matmul(original_weight);
        if let Some(b) = &self.bias {
            y = y.add_row_broadcast(b);
        }
        y
    }

    /// Quantized forward implementing the Figure-2a pseudocode. With a
    /// packed weight installed, the product is the real integer GEMM
    /// (activations quantized once into packed codes, i32 accumulation,
    /// scale folding on output); otherwise the simulated f32 QDQ product.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        // X R (feature transform on the activation side).
        let xr = self.feature.apply(x);
        let y = match &self.qweight {
            // Packed: Q_int(LXR) ⊗ Q_int(R⁻¹W) via qgemm.
            Some(qw) => qgemm(&self.stamp.quantize_transformed_packed(&xr), qw),
            // Simulated: Q(LXR) · (R⁻¹W) in f32.
            None => self.stamp.quantize_transformed(&xr).matmul(&self.weight),
        };
        // L⁻¹ (…), dropping transform padding rows.
        let mut out = self.stamp.inverse_trim(&y);
        // + 1βᵀ (bias is sequence-uniform so it commutes with L⁻¹, Eq. 7).
        if let Some(b) = &self.bias {
            out = out.add_row_broadcast(b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{ar1_covariance, cholesky};
    use crate::stats::sqnr;
    use crate::transforms::IdentityFeature;

    fn correlated(s: usize, d: usize, rho: f32, seed: u64) -> Tensor {
        let cov = ar1_covariance(s, rho, 1.0);
        cholesky(&cov).matmul(&Tensor::randn(&[s, d], seed))
    }

    #[test]
    fn stamp_improves_sqnr_on_correlated_activations() {
        let x = correlated(256, 64, 0.97, 31);
        let base = Stamp::new(
            StampConfig {
                transform: SeqTransformKind::Identity,
                hp_tokens: 0,
                ..Default::default()
            },
            256,
        );
        let stamp = Stamp::new(StampConfig { hp_tokens: 32, ..Default::default() }, 256);
        let s_base = sqnr(&x, &base.quantize_dequantize(&x));
        let s_stamp = sqnr(&x, &stamp.quantize_dequantize(&x));
        assert!(
            s_stamp > s_base + 3.0,
            "stamp {s_stamp:.2} dB vs base {s_base:.2} dB"
        );
    }

    #[test]
    fn all_transforms_functional() {
        let x = correlated(64, 32, 0.9, 32);
        for kind in [
            SeqTransformKind::Identity,
            SeqTransformKind::HaarDwt,
            SeqTransformKind::Dct,
            SeqTransformKind::Wht,
            SeqTransformKind::HaarDwt2d { h: 8, w: 8 },
        ] {
            let st = Stamp::new(
                StampConfig { transform: kind, hp_tokens: 8, ..Default::default() },
                64,
            );
            let q = st.quantize_dequantize(&x);
            assert!(q.all_finite(), "{:?}", kind);
            assert!(sqnr(&x, &q) > 10.0, "{:?}: {}", kind, sqnr(&x, &q));
        }
    }

    #[test]
    fn average_bits_matches_paper() {
        let st = Stamp::new(
            StampConfig { granularity: Granularity::PerTensor, ..Default::default() },
            4096,
        );
        assert!((st.average_bits(1152) - 4.0625).abs() < 1e-9);
    }

    #[test]
    fn skip_first_token_preserves_sink() {
        let mut x = correlated(129, 32, 0.9, 33);
        // Massive outlier in token 0 (attention sink).
        for j in 0..32 {
            x.set(0, j, 500.0 * if j % 2 == 0 { 1.0 } else { -1.0 });
        }
        let st = Stamp::new(
            StampConfig { skip_first_token: true, hp_tokens: 16, ..Default::default() },
            129,
        );
        let q = st.quantize_dequantize(&x);
        // First token must survive at 8-bit fidelity.
        let first_sqnr = crate::stats::sqnr_slices(x.row(0), q.row(0));
        assert!(first_sqnr > 35.0, "sink token SQNR {first_sqnr}");
        // And the rest must round-trip sanely.
        assert!(sqnr(&x, &q) > 20.0);
    }

    #[test]
    fn klt_is_at_least_as_good_as_dwt() {
        let s = 64;
        let samples: Vec<Tensor> = (0..16).map(|i| correlated(s, 32, 0.95, 100 + i)).collect();
        let x = correlated(s, 32, 0.95, 999);
        let cfg = StampConfig { hp_tokens: 8, ..Default::default() };
        let klt = Stamp::with_klt(cfg.clone(), &samples);
        let dwt = Stamp::new(cfg, s);
        let s_klt = sqnr(&x, &klt.quantize_dequantize(&x));
        let s_dwt = sqnr(&x, &dwt.quantize_dequantize(&x));
        // KLT is optimal in expectation; allow 1 dB sampling slack.
        assert!(s_klt > s_dwt - 1.0, "klt {s_klt} vs dwt {s_dwt}");
    }

    #[test]
    fn stamp_linear_function_preservation_at_high_bits() {
        // At 16 bits the quantized layer must match the fp layer closely,
        // proving the L/R plumbing is function-preserving.
        let (s, din, dout) = (64, 32, 16);
        let x = correlated(s, din, 0.9, 41);
        let w = Tensor::randn(&[din, dout], 42);
        let bias: Vec<f32> = (0..dout).map(|i| i as f32 * 0.1).collect();
        let stamp = Stamp::new(
            StampConfig { hp_bits: 16, lp_bits: 16, hp_tokens: 0, ..Default::default() },
            s,
        );
        let layer = StampLinear::new(
            stamp,
            w.clone(),
            Some(bias.clone()),
            Box::new(crate::transforms::HadamardFeature::new(din, 7)),
        );
        let y_fp = x.matmul(&w).add_row_broadcast(&bias);
        let y_q = layer.forward(&x);
        let rel = y_q.max_abs_diff(&y_fp) / y_fp.abs_max();
        assert!(rel < 1e-2, "rel err {rel}");
    }

    #[test]
    fn stamp_linear_packed_matches_simulated_oracle() {
        // The packed forward must agree with the simulated pipeline run on
        // the QDQ'd weight — the only differences being f32-vs-integer
        // accumulation order inside the product.
        let (s, din, dout) = (64, 32, 16);
        let x = correlated(s, din, 0.95, 61);
        let w = Tensor::randn(&[din, dout], 62);
        let bias: Vec<f32> = (0..dout).map(|i| i as f32 * 0.05).collect();
        let mk_stamp = || Stamp::new(StampConfig { hp_tokens: 8, ..Default::default() }, s);
        let packed = StampLinear::new(
            mk_stamp(),
            w.clone(),
            Some(bias.clone()),
            Box::new(IdentityFeature::new(din)),
        )
        .with_packed_weight(4, None);
        assert!(packed.packed_weight().is_some());
        let y = packed.forward(&x);

        // Oracle: same pipeline with the simulated (QDQ) weight product —
        // the dequantized packed codes ARE the W4 QDQ weight (bit-for-bit,
        // see baselines::weights tests), back in [in, out] layout.
        let oracle_stamp = mk_stamp();
        let wq = QTensor::from_weight(&w, 4, None).dequantize().transpose();
        let q = oracle_stamp.quantize_transformed(&x);
        let mut want = oracle_stamp.inverse_trim(&q.matmul(&wq));
        want = want.add_row_broadcast(&bias);

        let tol = 1e-3 * want.abs_max().max(1.0);
        let diff = y.max_abs_diff(&want);
        assert!(diff <= tol, "packed forward diff {diff} > tol {tol}");
    }

    #[test]
    fn stamp_linear_quantized_better_with_dwt() {
        let (s, din, dout) = (128, 64, 32);
        let x = correlated(s, din, 0.97, 51);
        let w = Tensor::randn(&[din, dout], 52);
        let y_fp = x.matmul(&w);

        let mk = |kind: SeqTransformKind, hp: usize| {
            let stamp = Stamp::new(
                StampConfig { transform: kind, hp_tokens: hp, ..Default::default() },
                s,
            );
            StampLinear::new(stamp, w.clone(), None, Box::new(IdentityFeature::new(din)))
        };
        let s_id = sqnr(&y_fp, &mk(SeqTransformKind::Identity, 0).forward(&x));
        let s_dwt = sqnr(&y_fp, &mk(SeqTransformKind::HaarDwt, 16).forward(&x));
        assert!(s_dwt > s_id + 2.0, "dwt {s_dwt} vs id {s_id}");
    }
}
