//! Micro-benchmark harness (offline stand-in for criterion; DESIGN.md §3).
//!
//! Deterministic wall-clock measurement with warmup, fixed-duration
//! sampling, and robust statistics (median / p95). `cargo bench` targets
//! are declared with `harness = false` and drive this directly. Results
//! can be serialized as machine-readable JSON (`BENCH_<target>.json`
//! convention) so the perf trajectory is diffable across PRs, and
//! `STAMP_BENCH_QUICK` switches [`Harness::from_env`] to bounded CI-smoke
//! timings.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics (nanoseconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// Throughput in items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }

    /// One JSON object (hand-rolled — the offline build vendors no serde).
    fn json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{:.1},\"median_ns\":{:.1},\"p95_ns\":{:.1},\"min_ns\":{:.1}}}",
            json_escape(&self.name),
            self.iters,
            self.mean_ns,
            self.median_ns,
            self.p95_ns,
            self.min_ns
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        if c == '"' || c == '\\' {
            out.push('\\');
        }
        out.push(c);
    }
    out
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Harness configuration.
pub struct Harness {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: usize,
    results: Vec<BenchStats>,
}

impl Default for Harness {
    fn default() -> Self {
        Harness {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(600),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Harness {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick harness for CI-ish runs.
    pub fn quick() -> Self {
        Harness {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(150),
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Harness selected by the environment: [`Harness::quick`] when
    /// `STAMP_BENCH_QUICK` is set to anything but `0` (the CI smoke step),
    /// full timings otherwise.
    pub fn from_env() -> Self {
        match std::env::var("STAMP_BENCH_QUICK") {
            Ok(v) if v != "0" => Harness::quick(),
            _ => Harness::new(),
        }
    }

    /// Benchmark `f`, which must return something observable (prevents the
    /// optimizer from deleting the body via `std::hint::black_box`).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure individual iterations.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples_ns.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        assert!(!samples_ns.is_empty());
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let stats = BenchStats {
            name: name.to_string(),
            iters: n,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            median_ns: samples_ns[n / 2],
            p95_ns: samples_ns[((n as f64 * 0.95) as usize).min(n - 1)],
            min_ns: samples_ns[0],
        };
        println!("{}", stats.line());
        self.results.push(stats.clone());
        stats
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// All collected results as one machine-readable JSON document,
    /// stamped with the active worker count so 1-thread and N-thread runs
    /// are distinguishable in the trajectory.
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self.results.iter().map(|r| r.json()).collect();
        format!(
            "{{\"threads\":{},\"benchmarks\":[{}]}}\n",
            crate::parallel::num_threads(),
            rows.join(",")
        )
    }

    /// Write [`Harness::to_json`] to `path` (the `BENCH_<target>.json`
    /// convention). Bench mains pass a relative path, which cargo
    /// resolves against the *package* root (`rust/`) — cargo sets the
    /// bench binary's cwd there, not at the workspace root.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Print a header for the stats lines.
    pub fn header(title: &str) {
        println!("\n=== {title} ===");
        println!("{:<44} {:>12} {:>12} {:>12}", "benchmark", "median", "mean", "p95");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut h = Harness {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            max_iters: 10_000,
            results: Vec::new(),
        };
        let stats = h.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(stats.iters > 10);
        assert!(stats.mean_ns > 0.0);
        assert!(stats.median_ns <= stats.p95_ns);
        assert!(stats.min_ns <= stats.median_ns);
    }

    #[test]
    fn throughput_math() {
        let s = BenchStats {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e6, // 1 ms
            median_ns: 1e6,
            p95_ns: 1e6,
            min_ns: 1e6,
        };
        assert!((s.throughput(1000.0) - 1e6).abs() < 1.0); // 1k items / ms = 1M/s
    }

    #[test]
    fn json_output_is_well_formed() {
        let mut h = Harness {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(5),
            max_iters: 1000,
            results: Vec::new(),
        };
        h.bench("alpha \"quoted\"", || 1 + 1);
        h.bench("beta", || 2 + 2);
        let json = h.to_json();
        assert!(json.starts_with("{\"threads\":"));
        assert!(json.contains("\"benchmarks\":["));
        assert!(json.contains("\\\"quoted\\\""), "quotes must be escaped: {json}");
        assert!(json.contains("\"name\":\"beta\""));
        assert!(json.trim_end().ends_with("]}"));
        // Balanced braces/brackets (cheap well-formedness proxy without a
        // JSON parser in the dependency-free build).
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        let opens = json.matches('{').count();
        assert_eq!(opens, json.matches('}').count());
        assert_eq!(opens, 3); // document + 2 benchmark rows
    }

    #[test]
    fn from_env_defaults_to_full() {
        // The test environment does not set STAMP_BENCH_QUICK; the default
        // harness must use the full measurement window.
        if std::env::var("STAMP_BENCH_QUICK").is_err() {
            let h = Harness::from_env();
            assert_eq!(h.measure, Harness::new().measure);
        }
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
