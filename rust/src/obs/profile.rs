//! Opt-in kernel profiling: per-call-site timing for the GEMM entry
//! points, aggregated by (kernel, site) into calls / nanoseconds /
//! elements-processed counters and an effective GOP/s rate.
//!
//! The disabled path must be near-free because `tensor::matmul` and
//! `tensor::qgemm` sit under every prefill and decode token: each entry
//! point does one relaxed atomic load (`kernel_timer` returns `None`)
//! and skips everything else. When enabled, the *calling* thread times
//! the whole entry point — the fork-join fan-out inside `parallel::run`
//! is included in the measurement, so the reported GOP/s is the
//! effective multi-thread rate, not a per-worker rate.
//!
//! Call-site attribution rides on a thread-local [`KernelSite`] set by
//! RAII [`SiteGuard`]s: the decode engine marks chunked prefill and
//! fused decode steps, and `Gpt`'s logits head re-marks its final
//! projection, so one fused step correctly splits into `Decode` GEMMs
//! plus a `Logits` GEMM. Anything outside a guard lands in `Other`.
//! The counters are process-wide (kernels are free functions), which
//! matches how the microbench and example consume them; `reset` between
//! measured regions.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

static PROFILE_ENABLED: AtomicBool = AtomicBool::new(false);

/// Globally enable/disable kernel profiling (off by default; the
/// `[observability] kernel_profile` knob routes here).
pub fn set_kernel_profile(on: bool) {
    PROFILE_ENABLED.store(on, Ordering::Relaxed);
}

pub fn kernel_profile_enabled() -> bool {
    PROFILE_ENABLED.load(Ordering::Relaxed)
}

/// Which serving phase issued a kernel call (thread-local, set by
/// [`site_guard`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum KernelSite {
    Prefill = 0,
    Decode = 1,
    Logits = 2,
    Other = 3,
}

impl KernelSite {
    pub fn as_str(self) -> &'static str {
        match self {
            KernelSite::Prefill => "prefill",
            KernelSite::Decode => "decode",
            KernelSite::Logits => "logits",
            KernelSite::Other => "other",
        }
    }
}

/// Which GEMM entry point ran.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum KernelKind {
    Matmul = 0,
    MatmulTransb = 1,
    Qgemm = 2,
}

impl KernelKind {
    pub fn as_str(self) -> &'static str {
        match self {
            KernelKind::Matmul => "matmul",
            KernelKind::MatmulTransb => "matmul_transb",
            KernelKind::Qgemm => "qgemm",
        }
    }
}

const N_SITES: usize = 4;
const N_KINDS: usize = 3;

struct SiteCell {
    calls: AtomicU64,
    ns: AtomicU64,
    ops: AtomicU64,
}

impl SiteCell {
    const fn zero() -> Self {
        Self { calls: AtomicU64::new(0), ns: AtomicU64::new(0), ops: AtomicU64::new(0) }
    }
}

static COUNTERS: [[SiteCell; N_SITES]; N_KINDS] =
    [const { [const { SiteCell::zero() }; N_SITES] }; N_KINDS];

thread_local! {
    static KERNEL_SITE: Cell<KernelSite> = const { Cell::new(KernelSite::Other) };
}

/// Restores the previous thread-local site on drop.
pub struct SiteGuard {
    prev: KernelSite,
}

impl Drop for SiteGuard {
    fn drop(&mut self) {
        KERNEL_SITE.with(|s| s.set(self.prev));
    }
}

/// Mark kernel calls issued by this thread until the guard drops.
#[must_use = "the site reverts when the guard drops"]
pub fn site_guard(site: KernelSite) -> SiteGuard {
    let prev = KERNEL_SITE.with(|s| s.replace(site));
    SiteGuard { prev }
}

pub fn current_site() -> KernelSite {
    KERNEL_SITE.with(|s| s.get())
}

/// Start of a kernel entry point: `None` (one relaxed load) when
/// profiling is off, a timestamp when on. Pair with [`kernel_done`].
#[inline]
pub fn kernel_timer() -> Option<Instant> {
    if PROFILE_ENABLED.load(Ordering::Relaxed) { Some(Instant::now()) } else { None }
}

/// End of a kernel entry point: charge elapsed time and `ops`
/// (multiply-accumulate count, 2·m·n·k for a GEMM) to the
/// (kind, current site) cell. No-op when `t0` is `None`.
#[inline]
pub fn kernel_done(t0: Option<Instant>, kind: KernelKind, ops: u64) {
    let Some(t0) = t0 else { return };
    let ns = t0.elapsed().as_nanos() as u64;
    let cell = &COUNTERS[kind as usize][current_site() as usize];
    cell.calls.fetch_add(1, Ordering::Relaxed);
    cell.ns.fetch_add(ns, Ordering::Relaxed);
    cell.ops.fetch_add(ops, Ordering::Relaxed);
}

/// One aggregated (kernel, site) row of the profile.
#[derive(Clone, Debug)]
pub struct KernelStat {
    pub kind: &'static str,
    pub site: &'static str,
    pub calls: u64,
    pub ns: u64,
    pub ops: u64,
}

impl KernelStat {
    /// Effective throughput in billions of multiply-accumulate ops per
    /// second (ops/ns ≡ GOP/s).
    pub fn gops(&self) -> f64 {
        if self.ns == 0 { 0.0 } else { self.ops as f64 / self.ns as f64 }
    }
}

const ALL_KINDS: [KernelKind; N_KINDS] =
    [KernelKind::Matmul, KernelKind::MatmulTransb, KernelKind::Qgemm];
const ALL_SITES: [KernelSite; N_SITES] =
    [KernelSite::Prefill, KernelSite::Decode, KernelSite::Logits, KernelSite::Other];

/// Snapshot every (kernel, site) cell that saw at least one call.
pub fn kernel_profile_snapshot() -> Vec<KernelStat> {
    let mut out = Vec::new();
    for kind in ALL_KINDS {
        for site in ALL_SITES {
            let c = &COUNTERS[kind as usize][site as usize];
            let calls = c.calls.load(Ordering::Relaxed);
            if calls == 0 {
                continue;
            }
            out.push(KernelStat {
                kind: kind.as_str(),
                site: site.as_str(),
                calls,
                ns: c.ns.load(Ordering::Relaxed),
                ops: c.ops.load(Ordering::Relaxed),
            });
        }
    }
    out
}

/// Zero every counter (profiling enablement is untouched).
pub fn reset_kernel_profile() {
    for row in &COUNTERS {
        for c in row {
            c.calls.store(0, Ordering::Relaxed);
            c.ns.store(0, Ordering::Relaxed);
            c.ops.store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_guard_nests_and_restores() {
        assert_eq!(current_site(), KernelSite::Other);
        {
            let _g = site_guard(KernelSite::Decode);
            assert_eq!(current_site(), KernelSite::Decode);
            {
                let _h = site_guard(KernelSite::Logits);
                assert_eq!(current_site(), KernelSite::Logits);
            }
            assert_eq!(current_site(), KernelSite::Decode);
        }
        assert_eq!(current_site(), KernelSite::Other);
    }

    #[test]
    fn disabled_timer_records_nothing() {
        // Tests share the process-wide flag; this test only asserts the
        // None path is inert, which holds regardless of interleaving.
        let t0: Option<Instant> = None;
        let before: u64 = kernel_profile_snapshot().iter().map(|s| s.calls).sum();
        kernel_done(t0, KernelKind::Matmul, 1_000_000);
        let after: u64 = kernel_profile_snapshot().iter().map(|s| s.calls).sum();
        assert!(after >= before); // other tests may record concurrently
    }

    #[test]
    fn enabled_timer_charges_the_current_site() {
        // Charge through a synthetic timer rather than the process-wide
        // enable flag: other tests (config application) may flip the flag
        // concurrently, and the charge path only cares about `Some`.
        let _g = site_guard(KernelSite::Prefill);
        let t0 = Some(Instant::now());
        kernel_done(t0, KernelKind::Qgemm, 12345);
        let snap = kernel_profile_snapshot();
        let row = snap
            .iter()
            .find(|s| s.kind == "qgemm" && s.site == "prefill")
            .expect("qgemm/prefill row");
        assert!(row.calls >= 1);
        assert!(row.ops >= 12345);
        assert!(row.gops() >= 0.0);
    }
}
