//! Structured trace events and the bounded per-engine ring buffer.
//!
//! A `TraceRing` records typed, fixed-size `TraceEvent`s (stream id,
//! monotonic microsecond timestamp, token position) from the decode
//! engine and the streaming scheduler. The ring is bounded and
//! overwrite-oldest: recording never blocks on a consumer and never
//! allocates after construction (the buffer is reserved up front and a
//! record is a plain slot write), so a stalled or absent drainer costs a
//! `dropped` counter, not memory. `drain` hands back the retained events
//! oldest-first and resets the ring; serialization to JSONL is done at
//! drain time, off the record path.

use std::sync::Mutex;

/// Sentinel stream id for events not tied to a seated stream (a `Shed`
/// happens before the request ever gets a `StreamId`). Serialized as
/// JSON `null`.
pub const SHED_STREAM: u64 = u64::MAX;

/// Typed trace event kinds covering the life of a stream: admission,
/// chunked prefill, fused decode steps, speculative draft/verify/
/// rollback, KV block finalization/eviction, pooled-prefix hits,
/// retirement, and scheduler sheds.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum TraceKind {
    Admit,
    PrefillChunk,
    DecodeStep,
    Draft,
    Verify,
    Rollback,
    BlockFinalize,
    Evict,
    PrefixHit,
    Retire,
    Shed,
}

impl TraceKind {
    pub fn as_str(self) -> &'static str {
        match self {
            TraceKind::Admit => "Admit",
            TraceKind::PrefillChunk => "PrefillChunk",
            TraceKind::DecodeStep => "DecodeStep",
            TraceKind::Draft => "Draft",
            TraceKind::Verify => "Verify",
            TraceKind::Rollback => "Rollback",
            TraceKind::BlockFinalize => "BlockFinalize",
            TraceKind::Evict => "Evict",
            TraceKind::PrefixHit => "PrefixHit",
            TraceKind::Retire => "Retire",
            TraceKind::Shed => "Shed",
        }
    }

    /// Inverse of [`TraceKind::as_str`].
    pub fn parse(s: &str) -> Option<TraceKind> {
        Some(match s {
            "Admit" => TraceKind::Admit,
            "PrefillChunk" => TraceKind::PrefillChunk,
            "DecodeStep" => TraceKind::DecodeStep,
            "Draft" => TraceKind::Draft,
            "Verify" => TraceKind::Verify,
            "Rollback" => TraceKind::Rollback,
            "BlockFinalize" => TraceKind::BlockFinalize,
            "Evict" => TraceKind::Evict,
            "PrefixHit" => TraceKind::PrefixHit,
            "Retire" => TraceKind::Retire,
            "Shed" => TraceKind::Shed,
            _ => return None,
        })
    }
}

/// One fixed-size trace record. `t_us` is microseconds since the owning
/// engine's epoch (monotonic `Instant`); `pos` is kind-dependent — the
/// prompt length for `Admit`, tokens prefilled so far for
/// `PrefillChunk`, generated-token count for `DecodeStep`/`Retire`, the
/// reused span for `PrefixHit`, cumulative block/row totals for
/// `BlockFinalize`/`Evict`, and for the speculative kinds the drafted
/// token count (`Draft`), accepted draft count (`Verify`), and rows
/// popped off the KV tail (`Rollback`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub kind: TraceKind,
    pub stream: u64,
    pub t_us: u64,
    pub pos: u64,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Scan a flat JSON object for `"key":` and return the raw value text.
fn raw_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find([',', '}'])?;
    Some(rest[..end].trim())
}

impl TraceEvent {
    /// One JSONL line for this event. The variant is stamped in at drain
    /// time (the ring is per-engine, so it is constant per drain).
    pub fn json(&self, variant: &str) -> String {
        let mut line = String::with_capacity(96);
        line.push_str("{\"event\":\"");
        line.push_str(self.kind.as_str());
        line.push_str("\",\"stream\":");
        if self.stream == SHED_STREAM {
            line.push_str("null");
        } else {
            line.push_str(&self.stream.to_string());
        }
        line.push_str(",\"t_us\":");
        line.push_str(&self.t_us.to_string());
        line.push_str(",\"pos\":");
        line.push_str(&self.pos.to_string());
        line.push_str(",\"variant\":\"");
        line.push_str(&json_escape(variant));
        line.push_str("\"}");
        line
    }

    /// Parse one line produced by [`TraceEvent::json`] (the variant
    /// label is not part of the event). Returns `None` on anything
    /// malformed — the round-trip test pins `json` → `from_json`
    /// identity.
    pub fn from_json(line: &str) -> Option<TraceEvent> {
        let ev = raw_field(line, "event")?;
        let kind = TraceKind::parse(ev.strip_prefix('"')?.strip_suffix('"')?)?;
        let stream = match raw_field(line, "stream")? {
            "null" => SHED_STREAM,
            s => s.parse().ok()?,
        };
        let t_us: u64 = raw_field(line, "t_us")?.parse().ok()?;
        let pos: u64 = raw_field(line, "pos")?.parse().ok()?;
        Some(TraceEvent { kind, stream, t_us, pos })
    }
}

struct RingInner {
    buf: Vec<TraceEvent>,
    /// Overwrite cursor == index of the oldest event once the ring is full.
    head: usize,
    /// Cumulative count of events overwritten before being drained.
    dropped: u64,
}

/// Bounded overwrite-oldest trace ring. One per engine; shared behind
/// `Arc<EngineObs>` so the scheduler thread and drain calls can reach it
/// while the engine records. A record is one short mutex-protected slot
/// write — no allocation (capacity is reserved up front), no consumer
/// coordination.
pub struct TraceRing {
    inner: Mutex<RingInner>,
    cap: usize,
}

impl TraceRing {
    /// Create a ring retaining at most `capacity` events (clamped to ≥ 1).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        Self {
            inner: Mutex::new(RingInner { buf: Vec::with_capacity(cap), head: 0, dropped: 0 }),
            cap,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Record one event, overwriting the oldest retained event when full.
    pub fn record(&self, ev: TraceEvent) {
        let mut g = self.inner.lock().unwrap();
        if g.buf.len() < self.cap {
            g.buf.push(ev);
        } else {
            let h = g.head;
            g.buf[h] = ev;
            g.head = (h + 1) % self.cap;
            g.dropped += 1;
        }
    }

    /// Number of events currently retained.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cumulative events overwritten before being drained (never reset).
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Take all retained events oldest-first and reset the ring (the
    /// `dropped` total is preserved across drains).
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut g = self.inner.lock().unwrap();
        let head = g.head;
        let mut out = Vec::with_capacity(g.buf.len());
        out.extend_from_slice(&g.buf[head..]);
        out.extend_from_slice(&g.buf[..head]);
        g.buf.clear();
        g.head = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: TraceKind, stream: u64, t_us: u64, pos: u64) -> TraceEvent {
        TraceEvent { kind, stream, t_us, pos }
    }

    #[test]
    fn ring_keeps_order_below_capacity() {
        let r = TraceRing::new(8);
        for i in 0..5 {
            r.record(ev(TraceKind::DecodeStep, 1, i, i));
        }
        let got = r.drain();
        assert_eq!(got.len(), 5);
        assert!(got.windows(2).all(|w| w[0].t_us < w[1].t_us));
        assert_eq!(r.dropped(), 0);
        assert!(r.is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let r = TraceRing::new(4);
        for i in 0..6 {
            r.record(ev(TraceKind::DecodeStep, 1, i, i));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 2);
        let got = r.drain();
        // The two oldest (t_us 0, 1) were overwritten.
        assert_eq!(got.iter().map(|e| e.t_us).collect::<Vec<_>>(), vec![2, 3, 4, 5]);
        // dropped is cumulative across drains.
        assert_eq!(r.dropped(), 2);
    }

    #[test]
    fn drain_resets_for_reuse() {
        let r = TraceRing::new(2);
        for i in 0..3 {
            r.record(ev(TraceKind::Admit, 0, i, 0));
        }
        r.drain();
        r.record(ev(TraceKind::Retire, 0, 9, 0));
        let got = r.drain();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].t_us, 9);
    }

    #[test]
    fn json_round_trips_every_kind() {
        let kinds = [
            TraceKind::Admit,
            TraceKind::PrefillChunk,
            TraceKind::DecodeStep,
            TraceKind::Draft,
            TraceKind::Verify,
            TraceKind::Rollback,
            TraceKind::BlockFinalize,
            TraceKind::Evict,
            TraceKind::PrefixHit,
            TraceKind::Retire,
            TraceKind::Shed,
        ];
        for (i, k) in kinds.into_iter().enumerate() {
            let e = ev(k, i as u64, 1000 + i as u64, 7 * i as u64);
            let line = e.json("gen");
            assert_eq!(TraceEvent::from_json(&line), Some(e), "line: {line}");
        }
    }

    #[test]
    fn shed_sentinel_serializes_as_null() {
        let e = ev(TraceKind::Shed, SHED_STREAM, 42, 0);
        let line = e.json("g");
        assert!(line.contains("\"stream\":null"), "line: {line}");
        assert_eq!(TraceEvent::from_json(&line), Some(e));
    }

    #[test]
    fn variant_label_is_escaped() {
        let e = ev(TraceKind::Admit, 0, 1, 2);
        let line = e.json("we\"ird\\name");
        assert!(line.contains("we\\\"ird\\\\name"), "line: {line}");
        // Escaping must not break the event fields.
        assert_eq!(TraceEvent::from_json(&line), Some(e));
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert_eq!(TraceEvent::from_json(""), None);
        assert_eq!(TraceEvent::from_json("{\"event\":\"Nope\",\"stream\":0,\"t_us\":0,\"pos\":0}"), None);
        assert_eq!(TraceEvent::from_json("{\"event\":\"Admit\",\"stream\":x,\"t_us\":0,\"pos\":0}"), None);
    }
}
