//! Fixed-bucket log2 histogram with a lock-free atomic record path.
//!
//! Latency distributions in the serving stack are heavy-tailed; the
//! mean-only accounting the coordinator started with hides exactly the
//! p95/p99 behavior production serving is judged on. `Histogram` trades
//! value resolution for a record path that is three relaxed atomic adds
//! (bucket, count, sum) — safe to call from every worker thread and from
//! the decode hot loop with no locks and no allocation.
//!
//! Bucketing: value `v` lands in bucket `64 - v.leading_zeros()`, i.e.
//! bucket 0 holds exactly `v == 0` and bucket `i ≥ 1` holds
//! `v ∈ [2^(i-1), 2^i - 1]`. The upper bound reported for a bucket
//! (`bucket_bound`) is therefore exact to within a factor of 2 — plenty
//! for microsecond latency quantiles — and the layout is fixed (65
//! buckets), which makes histograms mergeable by plain element-wise
//! addition and the Prometheus exposition cumulative buckets trivial.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log2 buckets: one for zero plus one per bit position of u64.
pub const N_BUCKETS: usize = 65;

/// Lock-free fixed-bucket log2 histogram (count, sum, 65 buckets).
///
/// All mutation goes through `&self` with relaxed atomics; readers see a
/// possibly slightly-stale but never torn view, which is the right
/// trade for metrics.
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Bucket index for a value: 0 for 0, else `64 - leading_zeros`.
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Inclusive upper bound of bucket `i` (0, 1, 3, 7, …, `u64::MAX`).
    pub fn bucket_bound(i: usize) -> u64 {
        match i {
            0 => 0,
            64.. => u64::MAX,
            _ => (1u64 << i) - 1,
        }
    }

    /// Record one sample. Three relaxed atomic adds; no locks.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean of the recorded samples (exact — from the true sum, not the
    /// bucket bounds). 0 when empty.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 { 0.0 } else { self.sum() as f64 / n as f64 }
    }

    /// Snapshot of the per-bucket counts.
    pub fn bucket_counts(&self) -> [u64; N_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Merge another histogram into this one by element-wise addition
    /// (the fixed bucket layout makes this exact).
    pub fn merge_from(&self, other: &Histogram) {
        for i in 0..N_BUCKETS {
            let c = other.buckets[i].load(Ordering::Relaxed);
            if c != 0 {
                self.buckets[i].fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
    }

    /// Quantile estimate: the upper bound of the bucket containing the
    /// `ceil(q·count)`-th sample (rank at least 1). Exact to within the
    /// factor-of-2 bucket width; 0 when the histogram is empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for i in 0..N_BUCKETS {
            cum += self.buckets[i].load(Ordering::Relaxed);
            if cum >= target {
                return Self::bucket_bound(i);
            }
        }
        Self::bucket_bound(N_BUCKETS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(7), 3);
        assert_eq!(Histogram::bucket_index(8), 4);
        assert_eq!(Histogram::bucket_index(u64::MAX), 64);
    }

    #[test]
    fn bucket_bound_covers_its_range() {
        // Every value maps into a bucket whose bound is >= the value and
        // whose predecessor's bound is < the value.
        for v in [0u64, 1, 2, 3, 4, 5, 63, 64, 65, 1000, 1 << 40, u64::MAX] {
            let i = Histogram::bucket_index(v);
            assert!(Histogram::bucket_bound(i) >= v, "bound({i}) < {v}");
            if i > 0 {
                assert!(Histogram::bucket_bound(i - 1) < v, "bound({}) >= {v}", i - 1);
            }
        }
    }

    #[test]
    fn count_sum_mean_quantiles() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1106);
        assert!((h.mean() - 221.2).abs() < 1e-9);
        // p50 is the 3rd sample (value 3, bucket [2,3] bound 3).
        assert_eq!(h.quantile(0.5), 3);
        // p99 rounds up to the 5th sample (1000, bucket [512,1023]).
        assert_eq!(h.quantile(0.99), 1023);
        // q = 0 clamps to rank 1.
        assert_eq!(h.quantile(0.0), 1);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn merge_is_elementwise_addition() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [1u64, 5, 9] {
            a.record(v);
        }
        for v in [2u64, 5, 1 << 20] {
            b.record(v);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 6);
        assert_eq!(a.sum(), 1 + 5 + 9 + 2 + 5 + (1 << 20));
        let direct = Histogram::new();
        for v in [1u64, 5, 9, 2, 5, 1 << 20] {
            direct.record(v);
        }
        assert_eq!(a.bucket_counts(), direct.bucket_counts());
    }

    #[test]
    fn concurrent_records_are_not_lost() {
        let h = std::sync::Arc::new(Histogram::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        h.record(t * 1000 + i);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 2000);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), 2000);
    }
}
