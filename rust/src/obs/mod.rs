//! Structured observability for the serving stack.
//!
//! Three pieces, each usable alone:
//!
//! - [`Histogram`] — fixed-bucket log2 latency histogram with a
//!   lock-free record path; `coordinator::metrics` uses it for queue
//!   wait, admission wait, and service time, and [`EngineObs`] for TTFT
//!   and time-per-output-token. Mergeable; p50/p90/p95/p99 via
//!   [`Histogram::quantile`].
//! - [`TraceRing`] — bounded overwrite-oldest ring of typed
//!   [`TraceEvent`]s (`Admit`, `PrefillChunk`, `DecodeStep`,
//!   `BlockFinalize`, `Evict`, `PrefixHit`, `Retire`, `Shed`) recorded
//!   by the decode engine and streaming scheduler, drainable to JSONL
//!   for per-stream timeline reconstruction. Enabled at runtime via the
//!   `[observability]` TOML section.
//! - kernel profiling ([`kernel_timer`]/[`kernel_done`] +
//!   [`site_guard`]) — opt-in per-call-site GEMM timing aggregated by
//!   (kernel, site) into elements-processed and effective GOP/s.
//!
//! [`EngineObs`] ties the engine-side pieces together: one per
//! `DecodeEngine`, holding the TTFT/TPOT histograms (always on — a few
//! relaxed atomics per token) and the optional trace ring. Both the
//! trace timestamp and the histogram sample for a given step are taken
//! from the *same* `now_us()` read, so a timeline reconstructed from
//! the drained trace agrees exactly with the histogram-recorded
//! latencies — `tests/obs.rs` pins that parity.

mod hist;
mod profile;
mod trace;

pub use hist::{Histogram, N_BUCKETS};
pub use profile::{
    current_site, kernel_done, kernel_profile_enabled, kernel_profile_snapshot, kernel_timer,
    reset_kernel_profile, set_kernel_profile, site_guard, KernelKind, KernelSite, KernelStat,
    SiteGuard,
};
pub use trace::{TraceEvent, TraceKind, TraceRing, SHED_STREAM};

use std::time::Instant;

/// Per-engine observability state: a monotonic epoch, the TTFT and
/// time-per-output-token histograms (always recorded), and the optional
/// trace ring (the opt-in cost).
///
/// Shared as `Arc<EngineObs>` between the owning `DecodeEngine`, the
/// coordinator's `VariantMetrics` (which links it so `Metrics::
/// prometheus()`/`to_json()` can surface TTFT/TPOT per variant), and
/// drain callers.
pub struct EngineObs {
    epoch: Instant,
    pub ttft_us: Histogram,
    pub tpot_us: Histogram,
    /// Accepted draft length per speculative verify step (tokens of the
    /// draft confirmed by the verifier — 0 when the first draft token
    /// already mismatched). Only recorded by speculative engines; empty
    /// otherwise. Unlike the latency histograms the unit is tokens, not
    /// microseconds.
    pub accepted_len: Histogram,
    trace: Option<TraceRing>,
}

impl EngineObs {
    /// Histograms only — no ring. The default for every engine.
    pub fn new() -> Self {
        Self::build(None)
    }

    /// Histograms plus a trace ring retaining `capacity` events.
    pub fn with_trace(capacity: usize) -> Self {
        Self::build(Some(TraceRing::new(capacity)))
    }

    fn build(trace: Option<TraceRing>) -> Self {
        Self {
            epoch: Instant::now(),
            ttft_us: Histogram::new(),
            tpot_us: Histogram::new(),
            accepted_len: Histogram::new(),
            trace,
        }
    }

    /// Microseconds since this engine's epoch (monotonic). Read this
    /// once per instrumented step and feed the same value to both the
    /// trace event and the histogram sample — that shared read is what
    /// makes trace-derived latencies equal histogram-recorded ones.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    #[inline]
    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some()
    }

    pub fn trace_capacity(&self) -> usize {
        self.trace.as_ref().map(TraceRing::capacity).unwrap_or(0)
    }

    /// Cumulative events overwritten before being drained (0 when no ring).
    pub fn trace_dropped(&self) -> u64 {
        self.trace.as_ref().map(TraceRing::dropped).unwrap_or(0)
    }

    /// Record a trace event; no-op (one `Option` check) when tracing is
    /// off.
    #[inline]
    pub fn record_event(&self, kind: TraceKind, stream: u64, t_us: u64, pos: u64) {
        if let Some(ring) = &self.trace {
            ring.record(TraceEvent { kind, stream, t_us, pos });
        }
    }

    /// Drain the retained events oldest-first (empty when no ring).
    pub fn drain_events(&self) -> Vec<TraceEvent> {
        self.trace.as_ref().map(TraceRing::drain).unwrap_or_default()
    }

    /// Drain to JSONL, one `\n`-terminated object per event, stamped
    /// with the variant label. Empty string when no ring or no events.
    pub fn drain_jsonl(&self, variant: &str) -> String {
        let events = self.drain_events();
        let mut out = String::with_capacity(events.len() * 96);
        for ev in &events {
            out.push_str(&ev.json(variant));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_obs_without_ring_is_inert() {
        let o = EngineObs::new();
        assert!(!o.trace_enabled());
        o.record_event(TraceKind::Admit, 0, o.now_us(), 4);
        assert!(o.drain_events().is_empty());
        assert_eq!(o.drain_jsonl("g"), "");
        assert_eq!(o.trace_capacity(), 0);
        assert_eq!(o.trace_dropped(), 0);
    }

    #[test]
    fn engine_obs_ring_round_trips_jsonl() {
        let o = EngineObs::with_trace(16);
        assert!(o.trace_enabled());
        let t0 = o.now_us();
        o.record_event(TraceKind::Admit, 1, t0, 8);
        o.record_event(TraceKind::DecodeStep, 1, t0 + 5, 1);
        o.record_event(TraceKind::Retire, 1, t0 + 9, 1);
        let jsonl = o.drain_jsonl("tiny");
        let parsed: Vec<TraceEvent> =
            jsonl.lines().map(|l| TraceEvent::from_json(l).expect("parse")).collect();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0].kind, TraceKind::Admit);
        assert_eq!(parsed[2].kind, TraceKind::Retire);
        assert!(jsonl.lines().all(|l| l.contains("\"variant\":\"tiny\"")));
        // Drained: the ring is empty for the next window.
        assert!(o.drain_events().is_empty());
    }

    #[test]
    fn now_us_is_monotone() {
        let o = EngineObs::new();
        let a = o.now_us();
        let b = o.now_us();
        assert!(b >= a);
    }
}
