//! Table/figure output formatting: aligned text tables for the terminal
//! plus CSV emission for downstream plotting. Every eval binary goes
//! through this so the paper-reproduction artifacts have one format.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table builder.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Format a float with sensible precision for table cells.
    pub fn num(v: f64) -> String {
        if v.is_infinite() {
            return "inf".to_string();
        }
        if v.abs() >= 1000.0 {
            format!("{v:.0}")
        } else if v.abs() >= 100.0 {
            format!("{v:.1}")
        } else {
            format!("{v:.2}")
        }
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}", c, w = widths[i] + 2);
                let _ = i;
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().map(|w| w + 2).sum::<usize>();
        let _ = writeln!(out, "{}", "-".repeat(total.min(120)));
        for row in &self.rows {
            line(&mut out, row);
        }
        let _ = ncol;
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV next to printing; returns the rendered text.
    pub fn emit(&self, csv_dir: Option<&Path>) -> std::io::Result<String> {
        if let Some(dir) = csv_dir {
            std::fs::create_dir_all(dir)?;
            let slug: String = self
                .title
                .chars()
                .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
                .collect();
            let mut f = std::fs::File::create(dir.join(format!("{slug}.csv")))?;
            f.write_all(self.to_csv().as_bytes())?;
        }
        Ok(self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["short".into(), "1.00".into()]);
        t.row(vec!["much-longer-name".into(), "2.00".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("much-longer-name"));
        // Both value cells start at the same column.
        let lines: Vec<&str> = s.lines().collect();
        let col = lines[1].find("value").unwrap();
        assert_eq!(&lines[3][col..col + 4], "1.00");
        assert_eq!(&lines[4][col..col + 4], "2.00");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn num_formatting() {
        assert_eq!(Table::num(f64::INFINITY), "inf");
        assert_eq!(Table::num(6.139), "6.14");
        assert_eq!(Table::num(668.2), "668.2");
        assert_eq!(Table::num(99723.0), "99723");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
