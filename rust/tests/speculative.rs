//! Speculative-decode parity: the tentpole invariant of PR 10.
//!
//! Greedy speculative output is **bit-identical** to the plain greedy
//! engine — for either drafter, at any draft depth, any thread count,
//! any batch composition, any admission schedule, and under every KV
//! policy (fp32, packed two-level, sliding-window eviction, pooled
//! prefix cache). The drafter only moves *throughput*; the verify step
//! recomputes every emitted token with the target model, and the
//! rollback restores the cache to exactly the plain path's state
//! (DESIGN.md §18). CI re-runs this file under `STAMP_THREADS=1` as
//! well; the property harness additionally forces serial kernels per
//! case.

use stamp::decode::{DecodeEngine, DraftKind, GenRequest, Sampling, SpecConfig, StreamResult};
use stamp::kvcache::{KvCache, KvCacheConfig};
use stamp::model::{FpHook, Gpt, GptConfig};
use stamp::stamp::SeqTransformKind;
use stamp::testkit;
use std::collections::HashMap;
use std::sync::Arc;

fn prompt_tokens(n: usize, salt: usize) -> Vec<u32> {
    (0..n).map(|i| ((i * 7 + salt * 11 + 3) % 70) as u32).collect()
}

/// PR 3's serial greedy loop: the ultimate content oracle.
fn serial_greedy(gpt: &Gpt, kv: &KvCacheConfig, prompt: &[u32], n_new: usize) -> Vec<u32> {
    let mut cache = KvCache::new(gpt.cfg.n_layers, kv.clone());
    gpt.generate_greedy(&FpHook, prompt, n_new, &mut cache)
}

fn spec_engine(
    gpt: &Arc<Gpt>,
    kv: &KvCacheConfig,
    draft: DraftKind,
    k: usize,
    decode_batch: usize,
) -> DecodeEngine {
    DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy)
        .with_decode_batch(decode_batch)
        .with_speculative(SpecConfig { draft, k })
}

/// Admit `reqs` into the engine following `gaps` (steps to run before
/// each admission), then step to completion. Returns every retired
/// stream keyed by its engine-assigned id (admission order).
fn drive(
    eng: &mut DecodeEngine,
    reqs: &[GenRequest],
    gaps: &[usize],
) -> HashMap<u64, StreamResult> {
    let mut out: Vec<(u64, StreamResult)> = Vec::new();
    for (r, &gap) in reqs.iter().zip(gaps) {
        for _ in 0..gap {
            eng.step(&FpHook);
            out.extend(eng.drain());
        }
        while eng.free_slots() == 0 {
            eng.step(&FpHook);
            out.extend(eng.drain());
        }
        eng.admit(r.clone()).expect("admission");
    }
    while eng.has_work() {
        eng.step(&FpHook);
        out.extend(eng.drain());
    }
    out.into_iter().collect()
}

#[test]
fn speculative_matches_plain_across_cache_policies() {
    // Deterministic sweep: both drafters × several depths × the four KV
    // policy families, one-shot `run_fp`, plain engine on the *same*
    // policy as the oracle.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 71));
    let reqs = vec![
        GenRequest { prompt: prompt_tokens(5, 0), n_new: 18 },
        GenRequest { prompt: prompt_tokens(13, 1), n_new: 7 },
        GenRequest { prompt: prompt_tokens(2, 2), n_new: 12 },
    ];
    let policies = [
        KvCacheConfig::fp32(),
        KvCacheConfig::two_level(4, 8, 4, 8),
        KvCacheConfig::two_level(4, 8, 4, 8).with_transform(SeqTransformKind::HaarDwt),
        // Small window: eviction actually fires mid-decode (13 + 7 and
        // 5 + 18 both exceed sink 4 + window 12).
        KvCacheConfig::two_level(4, 8, 4, 8).with_window(4, 12),
    ];
    for kv in &policies {
        let mut plain = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy);
        let want = plain.run_fp(&reqs).unwrap();
        for draft in [DraftKind::Ngram, DraftKind::Packed] {
            for k in [1usize, 3, 6] {
                let mut eng = spec_engine(&gpt, kv, draft, k, 8);
                let got = eng.run_fp(&reqs).unwrap();
                assert_eq!(got, want, "{draft:?} k={k} kv={kv:?}");
                assert!(
                    eng.obs().accepted_len.count() > 0,
                    "{draft:?} k={k}: no verify steps recorded"
                );
            }
        }
    }
}

#[test]
fn speculative_matches_plain_under_forced_serial_kernels() {
    // Thread-count invariance of the speculative path itself: the same
    // engine re-run with forced-serial kernels reproduces the threaded
    // run bit-for-bit (CI additionally re-runs the whole file under
    // STAMP_THREADS=1).
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 73));
    let reqs = vec![
        GenRequest { prompt: prompt_tokens(9, 3), n_new: 14 },
        GenRequest { prompt: prompt_tokens(4, 4), n_new: 10 },
    ];
    for draft in [DraftKind::Ngram, DraftKind::Packed] {
        let kv = KvCacheConfig::two_level(4, 8, 4, 8);
        let mut eng = spec_engine(&gpt, &kv, draft, 4, 2);
        let threaded = eng.run_fp(&reqs).unwrap();
        stamp::parallel::set_kernel_serial(true);
        let serial = eng.run_fp(&reqs).unwrap();
        stamp::parallel::set_kernel_serial(false);
        assert_eq!(threaded, serial, "{draft:?}: serial-kernel run diverged");
    }
}

#[test]
fn speculative_matches_plain_with_warm_prefix_cache() {
    // Pooled prefix seating composes with speculation: the stream's
    // private fp32 tail (where every rollback lands) begins after the
    // pooled span, and `spec_headroom`'s flush cap keeps the verify
    // appends from ever finalizing a block into the shared pool.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 79));
    let kv = KvCacheConfig::two_level(4, 8, 4, 8).with_prefix_cache();
    let shared = prompt_tokens(16, 7);
    let reqs: Vec<GenRequest> = (0..3)
        .map(|i| {
            let mut p = shared.clone();
            p.extend(prompt_tokens(3, i).iter().map(|&t| t + 1));
            GenRequest { prompt: p, n_new: 9 }
        })
        .collect();
    let warm = GenRequest { prompt: shared.clone(), n_new: 1 };
    let mut plain = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy);
    plain.run_fp(std::slice::from_ref(&warm)).unwrap();
    let want = plain.run_fp(&reqs).unwrap();
    assert!(plain.prefix_hits() > 0, "workload must actually exercise pooled seating");
    for draft in [DraftKind::Ngram, DraftKind::Packed] {
        let mut eng = spec_engine(&gpt, &kv, draft, 4, 8);
        eng.run_fp(std::slice::from_ref(&warm)).unwrap();
        let got = eng.run_fp(&reqs).unwrap();
        assert_eq!(got, want, "{draft:?} with warm prefix cache");
        assert!(eng.prefix_hits() > 0, "{draft:?}: speculative engine must still pool-seat");
    }
}

#[derive(Debug)]
struct SpecCase {
    n_streams: usize,
    prompts: Vec<usize>,
    budgets: Vec<usize>,
    decode_batch: usize,
    k: usize,
    draft: DraftKind,
    /// 0 fp32 · 1 packed · 2 packed+window · 3 packed+prefix-cache.
    kv_kind: usize,
    /// Engine steps to run before admitting each stream — random
    /// admission interleaving, the composition axis the module docs
    /// promise can never change a stream's output.
    gaps: Vec<usize>,
    seed: u64,
}

/// The randomized pin: speculative == plain over random KV policies,
/// drafters, depths, ragged batch compositions, and admission
/// schedules — threaded and forced-serial.
#[test]
fn property_speculative_greedy_is_bit_identical_to_plain() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 83));
    testkit::check(
        "speculative-vs-plain-greedy",
        10,
        0x59EC,
        |g| {
            let n_streams = g.usize_in(1, 4);
            SpecCase {
                n_streams,
                prompts: (0..n_streams).map(|_| g.usize_in(1, 20)).collect(),
                budgets: (0..n_streams).map(|_| g.usize_in(0, 12)).collect(),
                decode_batch: g.usize_in(1, 4),
                k: g.usize_in(1, 6),
                draft: if g.usize_in(0, 1) == 0 { DraftKind::Ngram } else { DraftKind::Packed },
                kv_kind: g.usize_in(0, 3),
                gaps: (0..n_streams).map(|_| g.usize_in(0, 3)).collect(),
                seed: g.rng.next_u64(),
            }
        },
        |c| {
            let kv = match c.kv_kind {
                0 => KvCacheConfig::fp32(),
                1 => KvCacheConfig::two_level(4, 8, 4, 8),
                // prompts ≤ 20 admit fine; 20 + 12 can exceed the 4 + 20
                // residency, so eviction fires on the long compositions.
                2 => KvCacheConfig::two_level(4, 8, 4, 8).with_window(4, 20),
                _ => KvCacheConfig::two_level(4, 8, 4, 8).with_prefix_cache(),
            };
            let reqs: Vec<GenRequest> = (0..c.n_streams)
                .map(|i| GenRequest {
                    prompt: (0..c.prompts[i])
                        .map(|j| ((c.seed as usize + i * 13 + j * 7) % 70) as u32)
                        .collect(),
                    n_new: c.budgets[i],
                })
                .collect();
            let mut plain = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy)
                .with_decode_batch(c.decode_batch);
            let want = drive(&mut plain, &reqs, &c.gaps);
            let mut eng = spec_engine(&gpt, &kv, c.draft, c.k, c.decode_batch);
            let got = drive(&mut eng, &reqs, &c.gaps);
            if got != want {
                return Err(format!("threaded speculative diverged: {got:?} vs {want:?}"));
            }
            // Same case again under forced-serial kernels.
            let mut eng = spec_engine(&gpt, &kv, c.draft, c.k, c.decode_batch);
            stamp::parallel::set_kernel_serial(true);
            let serial = drive(&mut eng, &reqs, &c.gaps);
            stamp::parallel::set_kernel_serial(false);
            if serial != want {
                return Err(format!("serial-kernel speculative diverged: {serial:?} vs {want:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn one_shot_run_on_a_busy_speculative_engine_requeues_foreign_retirees() {
    // Satellite: `run`/`run_fp` on an engine already holding speculative
    // streams claims only its own retirees; the foreign stream keeps
    // advancing, retires intact, and stays queued for the continuous
    // caller's `drain`.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 89));
    let kv = KvCacheConfig::fp32();
    let mut eng = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy)
        .with_speculative(SpecConfig { draft: DraftKind::Ngram, k: 4 });
    let foreign = GenRequest { prompt: prompt_tokens(6, 9), n_new: 30 };
    let fid = eng.admit(foreign.clone()).unwrap();
    let reqs = vec![
        GenRequest { prompt: prompt_tokens(4, 0), n_new: 6 },
        GenRequest { prompt: prompt_tokens(9, 1), n_new: 4 },
    ];
    let got = eng.run_fp(&reqs).unwrap();
    for (i, r) in reqs.iter().enumerate() {
        assert_eq!(
            got[i].tokens,
            serial_greedy(&gpt, &kv, &r.prompt, r.n_new),
            "one-shot stream {i}"
        );
        assert!(!got[i].truncated);
    }
    // Finish the foreign stream (it may already have retired mid-run —
    // then stepping is a no-op and the result is already queued).
    while eng.has_work() {
        eng.step(&FpHook);
    }
    let drained = eng.drain();
    assert_eq!(drained.len(), 1, "exactly the foreign stream: {drained:?}");
    assert_eq!(drained[0].0, fid);
    assert!(!drained[0].1.truncated);
    assert_eq!(
        drained[0].1.tokens,
        serial_greedy(&gpt, &kv, &foreign.prompt, foreign.n_new),
        "foreign stream must come back intact"
    );
}

#[test]
fn retirement_order_is_deterministic_across_identical_runs() {
    // Satellite: `drain` hands back (id, result) pairs in retirement
    // order, and that order is a pure function of the workload — two
    // identical speculative runs (and a forced-serial one) produce the
    // identical drain sequence, not just the same result set.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 97));
    let kv = KvCacheConfig::two_level(4, 8, 4, 8);
    let reqs: Vec<GenRequest> = (0..5)
        .map(|i| GenRequest { prompt: prompt_tokens(3 + 2 * i, i), n_new: 4 + 3 * i })
        .collect();
    let run = || {
        let mut eng = spec_engine(&gpt, &kv, DraftKind::Packed, 3, 2);
        for r in &reqs {
            eng.admit(r.clone()).unwrap();
        }
        let mut order = Vec::new();
        while eng.has_work() {
            eng.step(&FpHook);
            order.extend(eng.drain());
        }
        order
    };
    let a = run();
    assert_eq!(a.len(), reqs.len());
    let b = run();
    assert_eq!(a, b, "retirement order must be deterministic");
    stamp::parallel::set_kernel_serial(true);
    let c = run();
    stamp::parallel::set_kernel_serial(false);
    assert_eq!(a, c, "retirement order must not depend on thread count");
}
