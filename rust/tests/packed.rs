//! Packed integer inference path: property tests holding the packed
//! subsystem (QTensor + qgemm) to the simulated-QDQ oracle, plus
//! integration coverage of the `NativeExecutor` packed serving path.
//!
//! Two invariants anchor the whole subsystem:
//!
//! 1. **Round-trip exactness** — `QTensor::quantize(x).dequantize()` is
//!    bit-for-bit the f32 QDQ output for every granularity and bit mix,
//!    so the packed path can never silently diverge from the simulated
//!    one.
//! 2. **GEMM parity** — `qgemm(quantize(x), qweight)` matches the oracle
//!    `qdq(x) · qdq(w)ᵀ` to within accumulated-rounding tolerance (the
//!    operands are *identical* quantized values; only f32-vs-integer
//!    accumulation differs).
//!
//! Failures shrink and report the generating seed via `stamp::testkit`.

use stamp::baselines::{quantize_weight, quantize_weight_packed, QuantStack, WeightQuantCfg};
use stamp::config::{RunConfig, ServeSpec};
use stamp::coordinator::Server;
use stamp::model::{Gpt, GptConfig};
use stamp::quant::{quantize_dequantize_rows, BitAllocation, Granularity, QTensor};
use stamp::tensor::{matmul_transb, qgemm, qgemm_scalar, Tensor};
use stamp::testkit;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn granularity_from(code: usize, block: usize) -> Granularity {
    match code {
        0 => Granularity::PerTensor,
        1 => Granularity::PerToken,
        2 => Granularity::MicroBlock { block: if block % 32 == 0 { 32 } else { 16 } },
        _ => Granularity::PerBlock { block },
    }
}

#[derive(Debug)]
struct GemmCase {
    m: usize,
    k: usize,
    n: usize,
    lp: u32,
    hp_tokens: usize,
    gran: Granularity,
    wcfg: WeightQuantCfg,
    seed: u64,
}

/// Satellite 1: `qgemm(quantize(x), qweight)` vs the QDQ oracle across
/// randomized shapes, bits ∈ {4, 8}, mixed two-level allocations, and all
/// three granularities on both operands.
#[test]
fn property_qgemm_matches_qdq_oracle() {
    testkit::check(
        "qgemm-vs-qdq-oracle",
        16,
        0x51A3,
        |g| {
            let m = g.usize_in(1, 48);
            let k = g.usize_in(1, 96);
            let n = g.usize_in(1, 40);
            let lp = if g.usize_in(0, 1) == 0 { 4 } else { 8 };
            let hp_tokens = g.usize_in(0, m);
            let gran = granularity_from(g.usize_in(0, 3), g.pow2_in(4, 32));
            let w_bits = if g.usize_in(0, 1) == 0 { 4 } else { 8 };
            let w_block = if g.usize_in(0, 1) == 0 { None } else { Some(g.pow2_in(8, 32)) };
            let seed = g.rng.next_u64();
            GemmCase {
                m,
                k,
                n,
                lp,
                hp_tokens,
                gran,
                wcfg: WeightQuantCfg { bits: w_bits, block: w_block },
                seed,
            }
        },
        |c| {
            let x = Tensor::randn(&[c.m, c.k], c.seed);
            // Weight in the model's [in, out] layout.
            let w = Tensor::randn(&[c.k, c.n], c.seed ^ 0x5DEE_CE66);
            let bits = BitAllocation::two_level(c.hp_tokens, 8, c.lp);
            let got = qgemm(
                &QTensor::quantize(&x, &bits, c.gran),
                &quantize_weight_packed(&w, &c.wcfg),
            );
            // Oracle: simulated QDQ on both operands, f32 matmul.
            let want = matmul_transb(
                &quantize_dequantize_rows(&x, &bits, c.gran),
                &quantize_weight(&w, &c.wcfg).transpose(),
            );
            let tol = 1e-3 * want.abs_max().max(1.0) as f64;
            let diff = got.max_abs_diff(&want) as f64;
            if diff > tol {
                return Err(format!("diff {diff:.3e} > tol {tol:.3e}"));
            }
            Ok(())
        },
    );
}

/// PR 9 tentpole invariant: the word-parallel SWAR kernel is
/// **bit-identical** to the scalar oracle — not merely close — across
/// randomized shapes, 4/8-bit mixes on both operands, and every
/// granularity pairing (including micro-block activations, aligned and
/// misaligned against the weight's groups). Runs threaded under the
/// default `cargo test` and serial under the CI `STAMP_THREADS=1` re-run
/// of this suite, so thread count is covered too.
#[test]
fn property_swar_qgemm_is_bit_identical_to_scalar() {
    testkit::check(
        "swar-qgemm-vs-scalar-oracle",
        24,
        0x5A4B,
        |g| {
            let m = g.usize_in(1, 40);
            let k = g.usize_in(1, 200);
            let n = g.usize_in(1, 32);
            let lp = if g.usize_in(0, 1) == 0 { 4 } else { 8 };
            let hp_tokens = g.usize_in(0, m);
            let gran = granularity_from(g.usize_in(0, 3), g.pow2_in(4, 32));
            let w_bits = if g.usize_in(0, 1) == 0 { 4 } else { 8 };
            let w_block = if g.usize_in(0, 1) == 0 { None } else { Some(g.pow2_in(8, 32)) };
            let seed = g.rng.next_u64();
            GemmCase {
                m,
                k,
                n,
                lp,
                hp_tokens,
                gran,
                wcfg: WeightQuantCfg { bits: w_bits, block: w_block },
                seed,
            }
        },
        |c| {
            let x = Tensor::randn(&[c.m, c.k], c.seed);
            let w = Tensor::randn(&[c.k, c.n], c.seed ^ 0x5DEE_CE66);
            let bits = BitAllocation::two_level(c.hp_tokens, 8, c.lp);
            let qa = QTensor::quantize(&x, &bits, c.gran);
            let qw = quantize_weight_packed(&w, &c.wcfg);
            let got = qgemm(&qa, &qw);
            let want = qgemm_scalar(&qa, &qw);
            if got != want {
                let diff = got.max_abs_diff(&want);
                return Err(format!("SWAR kernel diverged from scalar oracle (max |Δ| = {diff:.3e})"));
            }
            Ok(())
        },
    );
}

#[derive(Debug)]
struct PackCase {
    s: usize,
    d: usize,
    lp: u32,
    hp: u32,
    hp_tokens: usize,
    gran: Granularity,
    seed: u64,
}

/// Satellite 2: pack/unpack round-trip is *exact* — the packed
/// `dequantize` equals the f32 QDQ bit-for-bit for every granularity and
/// two-level bit mix (including sizes large enough to take the threaded
/// packing path).
#[test]
fn property_packed_roundtrip_is_exact() {
    testkit::check(
        "packed-roundtrip-bitexact",
        16,
        0xB17E,
        |g| {
            let s = g.usize_in(1, 512);
            let d = g.usize_in(1, 160);
            let lp = if g.usize_in(0, 1) == 0 { 4 } else { 8 };
            let hp = if g.usize_in(0, 1) == 0 { 4 } else { 8 };
            let hp_tokens = g.usize_in(0, s);
            let gran = granularity_from(g.usize_in(0, 3), g.pow2_in(4, 64));
            let seed = g.rng.next_u64();
            PackCase { s, d, lp, hp, hp_tokens, gran, seed }
        },
        |c| {
            let x = Tensor::randn(&[c.s, c.d], c.seed);
            let bits = BitAllocation::two_level(c.hp_tokens, c.hp, c.lp);
            let packed = QTensor::quantize(&x, &bits, c.gran).dequantize();
            let simulated = quantize_dequantize_rows(&x, &bits, c.gran);
            if packed != simulated {
                let diff = packed.max_abs_diff(&simulated);
                return Err(format!("packed path diverged from QDQ (max |Δ| = {diff:.3e})"));
            }
            Ok(())
        },
    );
}

fn packed_gpt_executor() -> (stamp::runtime::NativeExecutor, Arc<Gpt>) {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 17));
    // Drive the stack assembly off the TOML config switch.
    let cfg = RunConfig::from_toml_str(
        "[quant]\nbaseline = \"rtn\"\nstamp = false\npacked = true\nact_bits = 4\nhp_tokens = 8\n",
    )
    .unwrap();
    assert!(cfg.quant.packed, "config switch must parse");
    let mut stack = QuantStack::build(
        cfg.quant.baseline_kind().unwrap().unwrap(),
        &HashMap::new(),
        Some(cfg.quant.act_cfg()),
        Some(cfg.quant.weight_cfg()),
        None,
        5,
    );
    if cfg.quant.packed {
        stack = stack.with_packed();
    }
    let exec = stamp::runtime::NativeExecutor::new().with_gpt("gpt-packed", gpt.clone(), Some(stack));
    (exec, gpt)
}

fn token_row(n: usize) -> Tensor {
    let toks: Vec<f32> = (0..n).map(|i| ((i * 7 + 3) % 70) as f32).collect();
    Tensor::from_vec(&[1, n], toks)
}

/// Satellite 3a: packed serving is byte-identical whether the kernels run
/// serial (`STAMP_THREADS=1` semantics, forced via the kernel-serial flag)
/// or fanned out across threads.
#[test]
fn packed_executor_thread_count_invariant() {
    use stamp::coordinator::Executor;
    let (exec, _gpt) = packed_gpt_executor();
    let inputs: Vec<Tensor> = [8usize, 16, 24].iter().map(|&n| token_row(n)).collect();
    for input in &inputs {
        let threaded = exec.execute("gpt-packed", &[input]).unwrap().remove(0);
        stamp::parallel::set_kernel_serial(true);
        let serial = exec.execute("gpt-packed", &[input]).unwrap().remove(0);
        stamp::parallel::set_kernel_serial(false);
        assert!(threaded.all_finite());
        assert_eq!(
            threaded, serial,
            "packed response differs between serial and threaded kernels"
        );
    }
}

/// Satellite 3b: the coordinator still batches the packed variant, and the
/// served bytes equal the direct executor call (workers are kernel-serial,
/// which by 3a equals the threaded result).
#[test]
fn serve_packed_deterministic() {
    use stamp::coordinator::Executor;
    let (exec, _gpt) = packed_gpt_executor();
    let exec = Arc::new(exec);
    let input = token_row(12);
    let want = exec.execute("gpt-packed", &[&input]).unwrap().remove(0);

    let spec = ServeSpec { workers: 3, max_batch: 4, max_wait_us: 500, queue_depth: 32 };
    let server = Server::start(&spec, &["gpt-packed"], exec);
    let handle = server.handle();
    let rxs: Vec<_> =
        (0..24).map(|_| handle.submit("gpt-packed", input.clone()).1).collect();
    for rx in &rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let out = resp.output.unwrap();
        assert_eq!(out, want, "served packed response differs from inline execution");
    }
    let vm = handle.metrics.variant("gpt-packed");
    assert!(vm.mean_batch_size() > 1.0, "batching never engaged");
    server.shutdown();
}
