//! Continuous-batching admission-interleaving parity harness (PR 6).
//!
//! The in-flight admission invariant: a stream's token output is a pure
//! function of (model, kv config, sampling spec, its own prompt and
//! budget) — *when* it was admitted, which streams it shared steps with,
//! how wide the fused chunks were, and how many kernel threads ran are
//! all invisible, bit for bit. The suite drives random workloads (ragged
//! prompts, budgets, arrival steps, windowed/bounded kv, fp32 + packed
//! caches) through a seeded scheduler trace and checks every stream
//! against the serial PR 3 oracle, threaded and forced-serial (CI also
//! re-runs the whole file under `STAMP_THREADS=1`).

use stamp::decode::{DecodeEngine, GenRequest, Sampling, StreamId, StreamResult};
use stamp::kvcache::{KvCache, KvCacheConfig};
use stamp::model::{FpHook, Gpt, GptConfig};
use stamp::testkit;
use std::collections::HashMap;
use std::sync::Arc;

/// Serial oracle: PR 3's per-request greedy loop, one private cache.
fn serial_greedy(gpt: &Gpt, kv: &KvCacheConfig, prompt: &[u32], n_new: usize) -> Vec<u32> {
    let mut cache = KvCache::new(gpt.cfg.n_layers, kv.clone());
    gpt.generate_greedy(&FpHook, prompt, n_new, &mut cache)
}

/// Drive an engine against an admission schedule: stream `i` becomes
/// available at engine step `arrivals[i]` and is seated in arrival order
/// as slots free up; the engine keeps stepping whatever is already in
/// flight in the meantime — the continuous-batching loop.
fn drive(
    engine: &mut DecodeEngine,
    reqs: &[GenRequest],
    arrivals: &[usize],
) -> Vec<StreamResult> {
    let mut order: Vec<usize> = (0..reqs.len()).collect();
    order.sort_by_key(|&i| arrivals[i]);
    let mut ids: HashMap<StreamId, usize> = HashMap::new();
    let mut done: Vec<Option<StreamResult>> = (0..reqs.len()).map(|_| None).collect();
    let (mut next, mut step) = (0usize, 0usize);
    while next < order.len() || engine.has_work() {
        // FIFO seating: a stream never jumps an earlier arrival that is
        // still waiting for a slot.
        while next < order.len() && arrivals[order[next]] <= step && engine.free_slots() > 0 {
            let i = order[next];
            ids.insert(engine.admit(reqs[i].clone()).expect("admit"), i);
            next += 1;
        }
        engine.step(&FpHook);
        for (sid, res) in engine.drain() {
            done[ids[&sid]] = Some(res);
        }
        step += 1;
        assert!(step < 100_000, "admission driver failed to converge");
    }
    done.into_iter().map(|r| r.expect("every admitted stream must retire")).collect()
}

#[derive(Debug)]
struct Workload {
    prompts: Vec<usize>,
    budgets: Vec<usize>,
    /// Engine step at which each stream arrives (the scheduler trace).
    arrivals: Vec<usize>,
    decode_batch: usize,
    max_inflight: usize,
    packed: bool,
    /// Sliding-window size (0 = bounded, no eviction policy). Generated
    /// ≥ any stream's prompt + budget so eviction is a no-op and the
    /// unwindowed serial oracle must still match bit-for-bit.
    window: usize,
    seed: u64,
}

impl Workload {
    fn base_kv(&self) -> KvCacheConfig {
        if self.packed { KvCacheConfig::two_level(4, 8, 4, 8) } else { KvCacheConfig::fp32() }
    }

    fn kv(&self) -> KvCacheConfig {
        let base = self.base_kv();
        if self.window > 0 { base.with_window(4, self.window) } else { base }
    }

    fn reqs(&self) -> Vec<GenRequest> {
        (0..self.prompts.len())
            .map(|i| GenRequest {
                prompt: (0..self.prompts[i])
                    .map(|j| ((self.seed as usize + i * 13 + j * 7) % 70) as u32)
                    .collect(),
                n_new: self.budgets[i],
            })
            .collect()
    }
}

fn gen_workload(g: &mut testkit::Gen) -> Workload {
    let n = g.usize_in(1, 6);
    Workload {
        prompts: (0..n).map(|_| g.usize_in(1, 24)).collect(),
        budgets: (0..n).map(|_| g.usize_in(0, 12)).collect(),
        arrivals: (0..n).map(|_| g.usize_in(0, 20)).collect(),
        decode_batch: g.usize_in(1, 4),
        max_inflight: g.usize_in(1, 4),
        packed: g.usize_in(0, 1) == 1,
        // prompts ≤ 24 and budgets ≤ 12 keep every logical length
        // ≤ 36 < 40 ≤ window: eviction can never fire.
        window: if g.usize_in(0, 2) == 0 { 0 } else { 40 + g.usize_in(0, 80) },
        seed: g.rng.next_u64(),
    }
}

/// Tentpole satellite: greedy in-flight admission equals serial decode
/// for every stream of every random workload, regardless of when the
/// stream was admitted — threaded and forced-serial kernels.
#[test]
fn property_inflight_admission_equals_serial_decode() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 31));
    testkit::check(
        "continuous-admission-vs-serial",
        8,
        0xC0117,
        gen_workload,
        |w| {
            let reqs = w.reqs();
            let mut engine = DecodeEngine::new(gpt.clone(), w.kv(), Sampling::Greedy)
                .with_decode_batch(w.decode_batch)
                .with_max_inflight(w.max_inflight);
            let threaded = drive(&mut engine, &reqs, &w.arrivals);
            // The same (reusable) engine, forced-serial kernels: the
            // fused path must be thread-count invariant.
            stamp::parallel::set_kernel_serial(true);
            let serial_kernels = drive(&mut engine, &reqs, &w.arrivals);
            stamp::parallel::set_kernel_serial(false);
            for (i, r) in reqs.iter().enumerate() {
                // The oracle always runs unwindowed: the no-op-sized
                // window must change nothing.
                let want = serial_greedy(&gpt, &w.base_kv(), &r.prompt, r.n_new);
                if threaded[i].tokens != want {
                    return Err(format!(
                        "stream {i} (arrival {}): in-flight {:?} != serial {want:?}",
                        w.arrivals[i], threaded[i].tokens
                    ));
                }
                if threaded[i].truncated {
                    return Err(format!("stream {i}: unexpected truncation"));
                }
                if serial_kernels[i] != threaded[i] {
                    return Err(format!("stream {i}: thread-count variance"));
                }
            }
            Ok(())
        },
    );
}

/// Sampled streams carry their own seeded RNG, so even temperature/top-k
/// decoding is admission-schedule invariant: a staggered-arrival run and
/// a fresh one-shot `run_fp` over the same requests must agree token for
/// token.
#[test]
fn property_sampled_streams_ignore_admission_schedule() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 33));
    testkit::check(
        "continuous-admission-invariance-topk",
        8,
        0x70B5,
        gen_workload,
        |w| {
            let sampling =
                Sampling::TopK { k: 8, temperature: 0.9, seed: w.seed ^ 0x5EED };
            let reqs = w.reqs();
            let mut staggered = DecodeEngine::new(gpt.clone(), w.kv(), sampling.clone())
                .with_decode_batch(w.decode_batch)
                .with_max_inflight(w.max_inflight);
            let got = drive(&mut staggered, &reqs, &w.arrivals);
            let mut oneshot = DecodeEngine::new(gpt.clone(), w.kv(), sampling)
                .with_decode_batch(w.decode_batch)
                .with_max_inflight(w.max_inflight);
            let want = oneshot.run_fp(&reqs).map_err(|e| e.to_string())?;
            for i in 0..reqs.len() {
                if got[i] != want[i] {
                    return Err(format!(
                        "stream {i} (arrival {}): staggered {:?} != one-shot {:?}",
                        w.arrivals[i], got[i].tokens, want[i].tokens
                    ));
                }
            }
            Ok(())
        },
    );
}

/// End to end: five generate calls through the streaming server path
/// (`Server::start_streaming` → `StreamWorker` → the variant's resident
/// engine) with only two engine slots, so admission necessarily happens
/// in flight — every response still matches serial decode exactly and
/// the admission metrics balance.
#[test]
fn streaming_server_admits_in_flight_and_matches_serial_decode() {
    use stamp::config::ServeSpec;
    use stamp::coordinator::Server;
    use stamp::runtime::NativeExecutor;
    use stamp::tensor::Tensor;
    use std::sync::atomic::Ordering;
    use std::time::Duration;

    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 61));
    let exec = Arc::new(NativeExecutor::new().with_gpt_generate_cfg(
        "gen",
        gpt.clone(),
        None,
        KvCacheConfig::fp32(),
        64,
        Sampling::Greedy,
        4,
        2, // two slots: five requests force in-flight admission
        None,
    ));
    let spec = ServeSpec { workers: 1, max_batch: 4, max_wait_us: 500, queue_depth: 16 };
    let server =
        Server::start_streaming(&spec, &[], &["gen"], exec.clone(), Some(exec.clone()), None);
    let handle = server.handle();
    let prompts = [3usize, 11, 7, 1, 16];
    let budgets = [12usize, 4, 9, 6, 2];
    let mut pending = Vec::new();
    for (i, (&p, &n)) in prompts.iter().zip(&budgets).enumerate() {
        let prompt: Vec<u32> = (0..p).map(|j| ((i * 13 + j * 7 + 3) % 70) as u32).collect();
        let mut row = vec![n as f32];
        row.extend(prompt.iter().map(|&t| t as f32));
        let rx = handle.submit("gen", Tensor::from_vec(&[1, row.len()], row)).1;
        pending.push((prompt, n, rx));
    }
    for (i, (prompt, n, rx)) in pending.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("stream response");
        let out = resp.output.unwrap();
        let want = serial_greedy(&gpt, &KvCacheConfig::fp32(), &prompt, n);
        assert_eq!(out.shape(), &[1, n], "request {i}");
        for (j, &w) in want.iter().enumerate() {
            assert_eq!(out.at(0, j), w as f32, "request {i} token {j}");
        }
        assert_eq!(resp.batch_size, 1, "streams retire independently");
    }
    let vm = handle.metrics.variant("gen");
    assert_eq!(vm.admitted.load(Ordering::Relaxed), 5, "all five requests seated");
    assert_eq!(vm.shed.load(Ordering::Relaxed), 0, "nothing shed");
    assert_eq!(vm.inflight.load(Ordering::Relaxed), 0, "inflight gauge back to zero");
    server.shutdown();
}
