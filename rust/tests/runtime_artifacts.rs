//! Runtime integration: load every AOT artifact through PJRT and verify
//! numerics against the quantization semantics implemented in Rust.
//! Skipped (with a notice) when `make artifacts` hasn't run, and compiled
//! only under the `pjrt` cargo feature (the default build has no PJRT
//! engine to load artifacts with).
#![cfg(feature = "pjrt")]

use stamp::quant::{BitAllocation, Granularity, QuantScheme};
use stamp::runtime::{ArtifactRegistry, Engine};
use stamp::stats::sqnr;
use stamp::tensor::Tensor;
use stamp::transforms::{HaarDwt, SequenceTransform};

fn registry() -> Option<ArtifactRegistry> {
    let dir = std::env::var("STAMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match ArtifactRegistry::load(&dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping runtime tests: {e} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn all_artifacts_compile_and_run() {
    let Some(reg) = registry() else { return };
    let engine = Engine::cpu().expect("PJRT CPU client");
    assert!(!reg.entries().is_empty());
    for entry in reg.entries() {
        let exe = engine.load(&reg.path_for(entry)).unwrap_or_else(|e| panic!("{e}"));
        let inputs: Vec<Tensor> = entry
            .input_shapes()
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::randn(s, 40 + i as u64).scale(0.2))
            .collect();
        let outputs = engine.run(&exe, &inputs).unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        let out_shapes = entry.output_shapes();
        assert_eq!(outputs.len(), out_shapes.len(), "{}", entry.name);
        for (o, s) in outputs.iter().zip(&out_shapes) {
            assert_eq!(o.shape(), &s[..], "{}", entry.name);
            assert!(o.all_finite(), "{}: non-finite", entry.name);
        }
    }
}

/// The `stamp_qdq` artifact (Pallas DWT + mixed QDQ lowered by jax) must
/// match the Rust-native implementation of the same math — the strongest
/// cross-layer consistency check in the repo.
#[test]
fn stamp_qdq_artifact_matches_rust() {
    let Some(reg) = registry() else { return };
    let Some(entry) = reg.get("stamp_qdq") else { return };
    let engine = Engine::cpu().expect("PJRT CPU client");
    let exe = engine.load(&reg.path_for(entry)).expect("compiles");
    let shape = &entry.input_shapes()[0];
    let s = shape[0];

    let x = Tensor::randn(shape, 77).scale(1.3);
    let got = engine.run(&exe, &[x.clone()]).expect("runs").remove(0);

    // Rust-native: 3-level DWT + two-level {8b x 8, 4b} per-token QDQ.
    let dwt = HaarDwt::new(s, 3);
    let scheme = QuantScheme {
        granularity: Granularity::PerToken,
        bits: BitAllocation::two_level(8, 8, 4),
    };
    let want = dwt.inverse(&scheme.apply(&dwt.forward(&x)));

    let fidelity = sqnr(&want, &got);
    assert!(
        fidelity > 35.0,
        "jax-lowered and rust-native STaMP QDQ disagree: {fidelity:.1} dB"
    );
}

/// FP model artifact sanity: output differs from input (it computes) and
/// the quantized-model artifact tracks the FP one at reasonable fidelity.
#[test]
fn model_artifacts_consistent() {
    let Some(reg) = registry() else { return };
    let (Some(fp), Some(qt)) = (reg.get("model_fp"), reg.get("model_stamp")) else { return };
    let engine = Engine::cpu().expect("PJRT CPU client");
    let fp_exe = engine.load(&reg.path_for(fp)).expect("fp compiles");
    let qt_exe = engine.load(&reg.path_for(qt)).expect("stamp compiles");
    let shape = &fp.input_shapes()[0];
    let x = Tensor::randn(shape, 99).scale(0.5);
    let y_fp = engine.run(&fp_exe, &[x.clone()]).expect("fp runs").remove(0);
    let y_qt = engine.run(&qt_exe, &[x.clone()]).expect("stamp runs").remove(0);
    assert!(y_fp.max_abs_diff(&x) > 1e-3, "model is not the identity");
    let fidelity = sqnr(&y_fp, &y_qt);
    assert!(fidelity > 3.0, "quantized model too far from FP: {fidelity:.1} dB");
    assert!(fidelity.is_finite(), "quantized model identical to FP — quant not applied?");
}
