//! Observability integration suite (PR 8).
//!
//! Pins the tentpole contracts end to end:
//!
//! - **Trace ↔ histogram parity**: TTFT and per-token latencies
//!   reconstructed from a drained trace equal the engine's histogram
//!   contents *exactly* (same bucket counts, same sums) — both sides of
//!   each sample come from one shared `now_us()` read.
//! - **JSONL round-trip + timeline shape**: every drained line parses
//!   back via `TraceEvent::from_json`, and each stream's events run
//!   `Admit` → … → `Retire` with one `DecodeStep` per generated token
//!   and a monotone per-stream clock.
//! - **Bounded ring**: a tiny ring overwrites oldest, counts drops, and
//!   retains the newest window.
//! - **Expositions**: the streaming server path surfaces per-variant
//!   TTFT/TPOT quantiles in both `Metrics::prometheus()` and
//!   `Metrics::to_json()`, and `Server::drain_trace` hands back
//!   parseable JSONL whose derived TTFT matches the exposed histogram.
//! - **Kernel profiling**: enabled profiling attributes GEMMs to the
//!   prefill/decode/logits sites.

use stamp::decode::{DecodeEngine, GenRequest, Sampling};
use stamp::kvcache::KvCacheConfig;
use stamp::model::{Gpt, GptConfig};
use stamp::obs::{EngineObs, Histogram, TraceEvent, TraceKind};
use std::collections::HashMap;
use std::sync::Arc;

fn traced_engine(seed: u64, capacity: usize) -> DecodeEngine {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), seed));
    DecodeEngine::new(gpt, KvCacheConfig::fp32(), Sampling::Greedy)
        .with_obs(Arc::new(EngineObs::with_trace(capacity)))
}

/// Five ragged greedy streams; every budget ≥ 2 so each stream records
/// at least one TPOT sample.
fn workload() -> Vec<GenRequest> {
    (0..5)
        .map(|i| GenRequest {
            prompt: (0..3 + 2 * i).map(|j| ((i * 13 + j * 7 + 3) % 70) as u32).collect(),
            n_new: 4 + 3 * i,
        })
        .collect()
}

/// Rebuild TTFT/TPOT histograms from a drained trace: TTFT is the first
/// `DecodeStep` minus the stream's `Admit`, TPOT the deltas between
/// consecutive `DecodeStep`s of one stream. This is the consumer-side
/// timeline reconstruction the trace format promises.
fn derive_latencies(events: &[TraceEvent]) -> (Histogram, Histogram) {
    let mut admit: HashMap<u64, u64> = HashMap::new();
    let mut steps: HashMap<u64, Vec<u64>> = HashMap::new();
    for ev in events {
        match ev.kind {
            TraceKind::Admit => {
                admit.insert(ev.stream, ev.t_us);
            }
            TraceKind::DecodeStep => steps.entry(ev.stream).or_default().push(ev.t_us),
            _ => {}
        }
    }
    let ttft = Histogram::new();
    let tpot = Histogram::new();
    for (stream, ts) in &steps {
        ttft.record(ts[0] - admit[stream]);
        for w in ts.windows(2) {
            tpot.record(w[1] - w[0]);
        }
    }
    (ttft, tpot)
}

/// Tentpole acceptance: trace-derived TTFT/TPOT equal the
/// histogram-recorded distributions exactly — not approximately — down
/// to identical bucket counts and sums.
#[test]
fn trace_derived_ttft_and_tpot_match_the_histograms_exactly() {
    let mut engine = traced_engine(71, 4096);
    let reqs = workload();
    let results = engine.run_fp(&reqs).expect("run");
    assert_eq!(results.len(), reqs.len());
    let obs = engine.obs().clone();
    assert_eq!(obs.trace_dropped(), 0, "the ring must cover the whole workload");
    let events = obs.drain_events();

    let (ttft, tpot) = derive_latencies(&events);
    let n_new_total: usize = reqs.iter().map(|r| r.n_new).sum();
    assert_eq!(ttft.count(), reqs.len() as u64, "one TTFT sample per stream");
    assert_eq!(tpot.count(), (n_new_total - reqs.len()) as u64, "n_new-1 TPOT samples per stream");

    assert_eq!(ttft.count(), obs.ttft_us.count());
    assert_eq!(ttft.sum(), obs.ttft_us.sum());
    assert_eq!(ttft.bucket_counts(), obs.ttft_us.bucket_counts());
    assert_eq!(tpot.count(), obs.tpot_us.count());
    assert_eq!(tpot.sum(), obs.tpot_us.sum());
    assert_eq!(tpot.bucket_counts(), obs.tpot_us.bucket_counts());
}

#[test]
fn jsonl_round_trips_and_each_stream_runs_admit_to_retire() {
    let mut engine = traced_engine(73, 4096);
    let reqs = workload();
    engine.run_fp(&reqs).expect("run");
    let jsonl = engine.obs().drain_jsonl("tiny-fp");
    assert!(jsonl.lines().all(|l| l.contains("\"variant\":\"tiny-fp\"")), "{jsonl}");
    let events: Vec<TraceEvent> = jsonl
        .lines()
        .map(|l| TraceEvent::from_json(l).expect("every drained line parses"))
        .collect();

    // Group per stream, preserving drain (chronological) order. run_fp
    // admits in request order on an empty engine, so stream i == req i.
    let mut per: HashMap<u64, Vec<TraceEvent>> = HashMap::new();
    for ev in &events {
        per.entry(ev.stream).or_default().push(*ev);
    }
    assert_eq!(per.len(), reqs.len());
    for (stream, evs) in &per {
        let req = &reqs[*stream as usize];
        let first = evs.first().expect("non-empty");
        let last = evs.last().expect("non-empty");
        assert_eq!(first.kind, TraceKind::Admit, "stream {stream}");
        assert_eq!(first.pos, req.prompt.len() as u64, "Admit pos is the prompt length");
        assert_eq!(last.kind, TraceKind::Retire, "stream {stream}");
        assert_eq!(last.pos, req.n_new as u64, "Retire pos is the generated-token count");
        assert!(
            evs.windows(2).all(|w| w[0].t_us <= w[1].t_us),
            "stream {stream}: per-stream timeline must be monotone"
        );
        let decode_steps = evs.iter().filter(|e| e.kind == TraceKind::DecodeStep).count();
        assert_eq!(decode_steps, req.n_new, "one DecodeStep per generated token");
        let prefills = evs.iter().filter(|e| e.kind == TraceKind::PrefillChunk).count();
        assert!(prefills >= 1, "stream {stream}: at least one prefill chunk");
    }
    // Drains are destructive windows: a second drain is empty.
    assert!(engine.obs().drain_events().is_empty());
}

#[test]
fn bounded_ring_overwrites_oldest_and_retains_the_newest_window() {
    let mut engine = traced_engine(75, 8);
    engine.run_fp(&workload()).expect("run");
    let obs = engine.obs().clone();
    assert!(obs.trace_dropped() > 0, "a tiny ring must have overwritten events");
    let events = obs.drain_events();
    assert!(events.len() <= 8, "drain returns at most capacity events");
    // Overwrite-oldest keeps the newest suffix, which ends with the
    // final stream's Retire.
    assert_eq!(events.last().expect("non-empty").kind, TraceKind::Retire);
}

/// End to end through `Server::start_streaming`: both machine-readable
/// expositions carry per-variant TTFT/TPOT quantiles, the server drains
/// parseable JSONL, and the drained trace agrees with the exposed
/// histograms.
#[test]
fn streaming_server_exposes_quantiles_and_drains_trace() {
    use stamp::config::{ObsSpec, ServeSpec};
    use stamp::coordinator::Server;
    use stamp::runtime::NativeExecutor;
    use stamp::tensor::Tensor;
    use std::sync::atomic::Ordering;
    use std::time::Duration;

    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 77));
    let obs_cfg = ObsSpec {
        trace_enabled: true,
        trace_capacity: 4096,
        trace_sink: "memory".into(),
        kernel_profile: false,
    };
    let exec = Arc::new(
        NativeExecutor::new()
            .with_gpt_generate_cfg(
                "gen",
                gpt,
                None,
                KvCacheConfig::fp32(),
                64,
                Sampling::Greedy,
                4,
                4,
                None,
            )
            .with_observability(&obs_cfg),
    );
    let spec = ServeSpec { workers: 1, max_batch: 4, max_wait_us: 500, queue_depth: 16 };
    let server =
        Server::start_streaming(&spec, &[], &["gen"], exec.clone(), Some(exec.clone()), None);
    let handle = server.handle();
    let mut pending = Vec::new();
    for i in 0..4usize {
        let mut row = vec![(4 + i) as f32]; // budgets 4..7
        row.extend((0..3 + i).map(|j| ((i * 13 + j * 7 + 3) % 70) as f32));
        pending.push(handle.submit("gen", Tensor::from_vec(&[1, row.len()], row)).1);
    }
    for rx in pending {
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("stream response");
        resp.output.expect("success");
    }
    let vm = handle.metrics.variant("gen");
    assert_eq!(vm.admitted.load(Ordering::Relaxed), 4);

    // Prometheus: engine-linked TTFT/TPOT histograms + quantile gauges
    // per variant, alongside the admission histogram.
    let prom = handle.metrics.prometheus();
    for needle in [
        "# TYPE stamp_ttft_us histogram",
        "stamp_ttft_us_bucket{variant=\"gen\",le=\"+Inf\"} 4",
        "stamp_ttft_us_count{variant=\"gen\"} 4",
        "stamp_ttft_us_quantile{variant=\"gen\",quantile=\"0.5\"}",
        "stamp_ttft_us_quantile{variant=\"gen\",quantile=\"0.99\"}",
        "# TYPE stamp_tpot_us_quantile gauge",
        "stamp_tpot_us_quantile{variant=\"gen\",quantile=\"0.95\"}",
        "stamp_admit_wait_us_count{variant=\"gen\"} 4",
        "stamp_admitted_total{variant=\"gen\"} 4",
    ] {
        assert!(prom.contains(needle), "missing `{needle}` in:\n{prom}");
    }

    // JSON: ttft/tpot objects with p50..p99 keys once an engine is linked.
    let json = handle.metrics.to_json();
    for needle in ["\"ttft_us\":{\"count\":4", "\"tpot_us\":{\"count\":", "\"p50\":", "\"p99\":"] {
        assert!(json.contains(needle), "missing `{needle}` in:\n{json}");
    }

    // The server reaches the ring through its retained stream executor.
    let jsonl = server.drain_trace("gen");
    assert!(!jsonl.is_empty(), "traced run must drain events");
    let events: Vec<TraceEvent> = jsonl
        .lines()
        .map(|l| TraceEvent::from_json(l).expect("server-drained line parses"))
        .collect();
    // End-to-end parity: trace-derived TTFT equals the histogram the
    // expositions above were rendered from.
    let (ttft, _) = derive_latencies(&events);
    let obs = exec.engine_obs("gen").expect("gen is a generate variant");
    assert_eq!(ttft.count(), obs.ttft_us.count());
    assert_eq!(ttft.bucket_counts(), obs.ttft_us.bucket_counts());
    server.shutdown();
}

/// Opt-in kernel profiling attributes GEMM time to the serving phase
/// that issued it: chunked prefill, fused decode steps, and the logits
/// head each get their own site rows with nonzero op counts.
#[test]
fn kernel_profile_attributes_gemms_to_sites() {
    use stamp::obs::{kernel_profile_snapshot, reset_kernel_profile, set_kernel_profile};

    reset_kernel_profile();
    set_kernel_profile(true);
    let mut engine = traced_engine(79, 1024);
    engine
        .run_fp(&[GenRequest { prompt: vec![5, 1, 2, 9], n_new: 6 }])
        .expect("run");
    set_kernel_profile(false);

    let snap = kernel_profile_snapshot();
    for site in ["prefill", "decode", "logits"] {
        let rows: Vec<_> = snap.iter().filter(|s| s.site == site).collect();
        assert!(!rows.is_empty(), "no kernel rows attributed to site {site}: {snap:?}");
        assert!(rows.iter().any(|s| s.calls > 0 && s.ops > 0), "empty rows for site {site}");
        for row in rows {
            assert!(row.gops() >= 0.0);
        }
    }
}
