//! Prompt-prefix sharing parity harness (PR 7).
//!
//! The pool invariant: seating a stream on pooled prefix blocks is
//! invisible, bit for bit. A block's stored representation depends only
//! on its absolute base position and the engine-uniform cache config —
//! never on which stream produced it — so a stream decoded from a
//! pool-shared prefix must emit exactly the tokens of the same stream
//! decoded with a fully private cache, for fp32 and packed caches,
//! greedy and sampled decoding, at any kernel thread count (CI re-runs
//! this file under `STAMP_THREADS=1`). The storage half of the claim:
//! N streams seated on one prefix hold it physically once —
//! `BlockPool::resident_bits` counts it a single time while the
//! per-stream `storage_bits` sum counts it N times.

use stamp::decode::{DecodeEngine, GenRequest, Sampling, StreamResult};
use stamp::kvcache::KvCacheConfig;
use stamp::model::{Gpt, GptConfig};
use stamp::testkit;
use std::sync::Arc;

#[derive(Debug)]
struct Workload {
    /// Shared prompt prefix length; always ≥ one cache block so every
    /// admitted stream can hit the pool.
    shared: usize,
    /// Per-stream private prompt suffix lengths (non-empty, so the whole
    /// aligned prefix — never less — is the expected shared span).
    suffixes: Vec<usize>,
    budgets: Vec<usize>,
    packed: bool,
    sampled: bool,
    seed: u64,
}

/// Cache config under test: block 8 two-level packed, or block 4 fp32.
/// (The prefix cache itself is opted into per engine, not here.)
fn kv_for(w: &Workload) -> KvCacheConfig {
    if w.packed {
        KvCacheConfig::two_level(4, 8, 4, 8)
    } else {
        KvCacheConfig { block: 4, ..KvCacheConfig::fp32() }
    }
}

fn sampling_for(w: &Workload) -> Sampling {
    if w.sampled {
        Sampling::TopK { k: 8, temperature: 0.9, seed: w.seed ^ 0x5EED }
    } else {
        Sampling::Greedy
    }
}

fn gen_workload(g: &mut testkit::Gen) -> Workload {
    let n = g.usize_in(2, 5);
    let block = 8; // the larger of the two blocks under test
    Workload {
        shared: block * g.usize_in(1, 2) + g.usize_in(0, block - 1),
        suffixes: (0..n).map(|_| g.usize_in(1, 8)).collect(),
        budgets: (0..n).map(|_| g.usize_in(1, 8)).collect(),
        packed: g.usize_in(0, 1) == 1,
        sampled: g.usize_in(0, 1) == 1,
        seed: g.rng.next_u64(),
    }
}

fn prompts_for(w: &Workload) -> (Vec<u32>, Vec<GenRequest>) {
    let shared: Vec<u32> =
        (0..w.shared).map(|j| ((w.seed as usize + j * 7) % 70) as u32).collect();
    let reqs = (0..w.suffixes.len())
        .map(|i| {
            let mut prompt = shared.clone();
            prompt.extend(
                (0..w.suffixes[i]).map(|j| ((i * 13 + j * 11 + 5) % 70) as u32),
            );
            GenRequest { prompt, n_new: w.budgets[i] }
        })
        .collect();
    (shared, reqs)
}

/// Decode `reqs` on a pool-backed engine whose prefix cache was warmed by
/// running the shared prompt to completion first; returns the results and
/// the number of admissions seated on pooled blocks.
fn pooled_run(
    gpt: &Arc<Gpt>,
    kv: &KvCacheConfig,
    sampling: &Sampling,
    shared: &[u32],
    reqs: &[GenRequest],
) -> Result<(Vec<StreamResult>, u64), String> {
    let mut engine =
        DecodeEngine::new(gpt.clone(), kv.clone().with_prefix_cache(), sampling.clone());
    // The warmer registers every block-aligned prefix of the shared
    // prompt; it cannot hit an empty pool itself.
    engine
        .run_fp(&[GenRequest { prompt: shared.to_vec(), n_new: 1 }])
        .map_err(|e| e.to_string())?;
    let hits0 = engine.prefix_hits();
    if hits0 != 0 {
        return Err(format!("warm stream hit an empty pool ({hits0} hits)"));
    }
    let out = engine.run_fp(reqs).map_err(|e| e.to_string())?;
    Ok((out, engine.prefix_hits()))
}

/// Acceptance property: a stream decoded from a pool-shared prefix is
/// bit-identical — tokens, and therefore the logits they argmax/sample
/// from — to the same stream decoded with an unshared private cache,
/// threaded and forced-serial, fp32 and packed, greedy and top-k.
#[test]
fn property_prefix_shared_decode_is_bit_identical_to_unshared() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 53));
    testkit::check("prefix-shared-vs-unshared", 10, 0x9F1C5, gen_workload, |w| {
        let kv = kv_for(w);
        let sampling = sampling_for(w);
        let (shared, reqs) = prompts_for(w);
        // Reference: the same requests on an engine with no prefix cache —
        // every stream pays its own full prefill.
        let mut private = DecodeEngine::new(gpt.clone(), kv.clone(), sampling.clone());
        let want = private.run_fp(&reqs).map_err(|e| e.to_string())?;
        let (got, hits) = pooled_run(&gpt, &kv, &sampling, &shared, &reqs)?;
        if hits != reqs.len() as u64 {
            return Err(format!(
                "expected every admission to hit the warmed pool: {hits}/{}",
                reqs.len()
            ));
        }
        for i in 0..reqs.len() {
            if got[i] != want[i] {
                return Err(format!(
                    "stream {i}: pooled {:?} != unshared {:?}",
                    got[i].tokens, want[i].tokens
                ));
            }
        }
        // Forced-serial kernels must reproduce the threaded run exactly.
        stamp::parallel::set_kernel_serial(true);
        let serial = pooled_run(&gpt, &kv, &sampling, &shared, &reqs);
        stamp::parallel::set_kernel_serial(false);
        let (serial, serial_hits) = serial?;
        if serial_hits != hits {
            return Err(format!("thread-count hit variance: {serial_hits} != {hits}"));
        }
        for i in 0..reqs.len() {
            if serial[i] != got[i] {
                return Err(format!("stream {i}: thread-count variance"));
            }
        }
        Ok(())
    });
}

/// Acceptance property: N admitted shared-prefix streams account the
/// prefix N times logically but hold it once physically — right after
/// admission each stream's cache is exactly the pooled span, so the
/// per-stream `storage_bits` sum is N × the pool's resident footprint.
#[test]
fn property_shared_prefix_is_stored_once_across_streams() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 59));
    testkit::check("prefix-storage-counted-once", 10, 0x0B17, gen_workload, |w| {
        let kv = kv_for(w);
        let (shared, reqs) = prompts_for(w);
        let mut engine =
            DecodeEngine::new(gpt.clone(), kv.clone().with_prefix_cache(), Sampling::Greedy);
        engine
            .run_fp(&[GenRequest { prompt: shared.clone(), n_new: 1 }])
            .map_err(|e| e.to_string())?;
        // The retired warmer's aligned blocks stay resident, pinned by the
        // prefix index alone.
        let prefix_bits = engine.pool().resident_bits();
        if prefix_bits == 0 {
            return Err("warm run registered no resident prefix blocks".into());
        }
        let n = reqs.len();
        for r in &reqs {
            engine.admit(r.clone()).map_err(|e| e.to_string())?;
        }
        // Admission seats each stream on the full aligned prefix (every
        // suffix is non-empty) without prefilling anything yet: no private
        // blocks, no fp32 tail rows.
        if engine.prefix_hits() != n as u64 {
            return Err(format!("hits {} != streams {n}", engine.prefix_hits()));
        }
        if engine.inflight_tail_bits() != 0 {
            return Err(format!("unexpected tail bits {}", engine.inflight_tail_bits()));
        }
        if engine.pool().resident_bits() != prefix_bits {
            return Err(format!(
                "admission must not grow the pool: {} != {prefix_bits}",
                engine.pool().resident_bits()
            ));
        }
        let logical = engine.inflight_storage_bits();
        if logical != n * prefix_bits {
            return Err(format!(
                "per-stream sum must count the prefix N times: {logical} != {n} × {prefix_bits}"
            ));
        }
        // Physically it exists once: one prefix copy plus (empty) tails.
        let physical = engine.pool().resident_bits() + engine.inflight_tail_bits();
        if physical != prefix_bits {
            return Err(format!("prefix stored more than once: {physical} != {prefix_bits}"));
        }
        // Decode to completion: every stream retires cleanly, the gauge
        // empties, and the pool keeps only index-pinned blocks (the
        // streams' own registrations may extend past the warmer's).
        let hook = stamp::model::FpHook;
        while engine.has_work() {
            engine.step(&hook);
            engine.drain();
        }
        if engine.inflight_storage_bits() != 0 {
            return Err("retired streams must release their handles".into());
        }
        if engine.pool().resident_bits() < prefix_bits {
            return Err("index-pinned prefix blocks must survive stream retirement".into());
        }
        Ok(())
    });
}

/// Satellite regression: `kv.window` × `kv.prefix_cache`. A windowed
/// stream that has already evicted cannot vouch for its absolute prompt
/// prefix — its leading handles are post-gap blocks, not positions
/// `0..span` — so prefill-completion registration must decline entirely
/// (the `KvCache::prefix_entry` guard). Before the guard, a long warm
/// prompt would seed the index with a poisoned entry and every later
/// shared-prefix admission decoded from the wrong rows.
#[test]
fn windowed_engine_never_registers_an_evicted_prefix_and_stays_exact() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 67));
    // Block 8, sink span 8, window 16: prefill of a 40-token prompt
    // (chunked at the window budget) evicts block 1 before it finishes.
    let kv = KvCacheConfig::two_level(4, 8, 4, 8).with_window(4, 16);
    let shared: Vec<u32> = (0..40).map(|j| ((j * 7 + 3) % 70) as u32).collect();
    let reqs: Vec<GenRequest> = (0..3)
        .map(|i| {
            let mut prompt = shared.clone();
            prompt.extend((0..=i as u32).map(|j| (i as u32 * 13 + j * 11 + 5) % 70));
            GenRequest { prompt, n_new: 8 }
        })
        .collect();
    let mut pooled =
        DecodeEngine::new(gpt.clone(), kv.clone().with_prefix_cache(), Sampling::Greedy);
    pooled.run_fp(&[GenRequest { prompt: shared.clone(), n_new: 2 }]).unwrap();
    assert_eq!(
        pooled.pool().prefix_entries(),
        0,
        "an evicted warm stream must register nothing"
    );
    let got = pooled.run_fp(&reqs).unwrap();
    assert_eq!(pooled.prefix_hits(), 0, "nothing registered ⇒ nothing to hit");
    // Oracle: the same windowed config with no prefix cache at all.
    let mut private = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy);
    let want = private.run_fp(&reqs).unwrap();
    assert_eq!(got, want, "windowed decode must be unperturbed by the prefix-cache knob");
}

/// The complementary positive case: a windowed engine whose warm prompt
/// finishes prefill *before* any eviction registers normally, later
/// shared-prefix admissions seat on the pool, and streams that then
/// decode far enough to evict still match the private windowed oracle
/// bit for bit — pooled prefix blocks are immutable and
/// position-determined, so the index entry outlives the streams' own
/// evictions.
#[test]
fn windowed_engine_shares_a_pre_eviction_prefix_and_stays_exact() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 71));
    let kv = KvCacheConfig::two_level(4, 8, 4, 8).with_window(4, 16);
    // 24 tokens: three aligned blocks, all inside `sinks ∪ last-16` at
    // the end of the warm prefill — no eviction yet, so registration
    // covers aligned prefixes 8, 16 and 24.
    let shared: Vec<u32> = (0..24).map(|j| ((j * 7 + 3) % 70) as u32).collect();
    let mut pooled =
        DecodeEngine::new(gpt.clone(), kv.clone().with_prefix_cache(), Sampling::Greedy);
    pooled.run_fp(&[GenRequest { prompt: shared.clone(), n_new: 1 }]).unwrap();
    assert_eq!(pooled.pool().prefix_entries(), 3, "pre-eviction prefixes register");
    // Budgets push each stream's logical length past the resident bound
    // (24 + suffix + 16 > 32): every stream evicts *after* seating on the
    // pooled prefix.
    let reqs: Vec<GenRequest> = (0..3)
        .map(|i| {
            let mut prompt = shared.clone();
            prompt.extend((0..=i as u32).map(|j| (i as u32 * 13 + j * 11 + 5) % 70));
            GenRequest { prompt, n_new: 16 }
        })
        .collect();
    let got = pooled.run_fp(&reqs).unwrap();
    assert_eq!(pooled.prefix_hits(), 3, "every admission seats on the warmed pool");
    let mut private = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy);
    let want = private.run_fp(&reqs).unwrap();
    assert_eq!(got, want, "pool-seated windowed decode must equal the private run");
}

/// The fp32 no-window path without `prefix_cache` still never finalizes
/// blocks (`storage_bits` accounting is unchanged from PR 3), while the
/// same prompts with the knob set decode identically — the flag is purely
/// a storage-layout opt-in.
#[test]
fn prefix_cache_flag_does_not_change_fp32_decode_output() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 61));
    let kv = KvCacheConfig { block: 4, ..KvCacheConfig::fp32() };
    let reqs = vec![
        GenRequest { prompt: (0..13).map(|j| (j * 5 % 70) as u32).collect(), n_new: 6 },
        GenRequest { prompt: (0..9).map(|j| (j * 3 + 1) as u32).collect(), n_new: 4 },
    ];
    let mut plain = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy);
    let want = plain.run_fp(&reqs).unwrap();
    let mut pooled = DecodeEngine::new(gpt, kv.with_prefix_cache(), Sampling::Greedy);
    let got = pooled.run_fp(&reqs).unwrap();
    assert_eq!(got, want, "prefix_cache must not perturb fp32 decode");
    // No shared warm-up happened, so nothing could have been seated on
    // the pool mid-run (the second request's prompt is unrelated).
    assert_eq!(pooled.prefix_hits(), 0);
}
