//! Integration tests: cross-module flows that unit tests can't cover —
//! calibrate → quantize → evaluate → serve, config-driven stack assembly,
//! and coordinator end-to-end under a real quantized executor.

use stamp::baselines::{ActQuantCfg, BaselineKind, QuantHook, QuantStack, WeightQuantCfg};
use stamp::config::{RunConfig, ServeSpec};
use stamp::coordinator::{Executor, Server};
use stamp::data::{ActivationGenerator, ActivationSpec, Corpus, PromptSet};
use stamp::eval::perplexity;
use stamp::eval::tables::{calibrate_dit, calibrate_gpt};
use stamp::model::{Dit, DitConfig, FpHook, Gpt, GptConfig};
use stamp::stamp::{SeqTransformKind, Stamp, StampConfig};
use stamp::stats::sqnr;
use stamp::tensor::Tensor;
use std::sync::Arc;
use std::time::Duration;

/// Calibrate → build stack → eval: the Table-2 pipeline on one model,
/// asserting the full ordering FP < QuaRot+STaMP < QuaRot < RTN (in PPL).
#[test]
fn llm_pipeline_ordering() {
    let corpus = Corpus::generate(20_000, 5);
    let mut gpt = Gpt::new(GptConfig::tiny(), 6);
    let tc = stamp::train::TrainConfig { steps: 80, ..Default::default() };
    stamp::train::train_gpt(&mut gpt, &corpus, &tc, 1, |_, _| {});
    gpt.inject_outlier_channels(2, 30.0);

    let seqs_all = corpus.sequences(128);
    let seqs: Vec<&[u32]> = seqs_all.iter().take(2).cloned().collect();
    let stats = calibrate_gpt(&gpt, &corpus, 128);

    let fp = perplexity(&gpt, &FpHook, &seqs);
    let act = ActQuantCfg { hp_tokens: 8, ..ActQuantCfg::w4a4_per_token() };
    let rtn = QuantStack::build(
        BaselineKind::Rtn,
        &stats,
        Some(act.clone()),
        Some(WeightQuantCfg::w4_per_channel()),
        None,
        1,
    );
    let quarot = QuantStack::build(
        BaselineKind::QuaRot,
        &stats,
        Some(act.clone()),
        Some(WeightQuantCfg::w4_per_channel()),
        None,
        1,
    );
    let quarot_stamp = QuantStack::build(
        BaselineKind::QuaRot,
        &stats,
        Some(act),
        Some(WeightQuantCfg::w4_per_channel()),
        None,
        1,
    )
    .with_stamp(QuantStack::llm_stamp(SeqTransformKind::HaarDwt));

    let p_rtn = perplexity(&gpt, &QuantHook::new(&rtn), &seqs);
    let p_qr = perplexity(&gpt, &QuantHook::new(&quarot), &seqs);
    let p_qrs = perplexity(&gpt, &QuantHook::new(&quarot_stamp), &seqs);

    assert!(fp < p_qrs, "fp {fp} !< quarot+stamp {p_qrs}");
    assert!(p_qrs < p_rtn, "quarot+stamp {p_qrs} !< rtn {p_rtn}");
    assert!(p_qr < p_rtn, "quarot {p_qr} !< rtn {p_rtn}");
}

/// The LVM pipeline end-to-end: calibrated SVDQuant+STaMP beats plain RTN
/// on generation fidelity.
#[test]
fn lvm_pipeline_fidelity() {
    let dit = Dit::new(
        DitConfig { grid_h: 8, grid_w: 8, d_model: 64, n_heads: 4, n_layers: 2, d_ff: 128, ctx_tokens: 4, steps: 2 },
        3,
    );
    let stats = calibrate_dit(&dit);
    let act = ActQuantCfg { hp_tokens: 0, ..ActQuantCfg::w4a4_per_token() };
    let rtn = QuantStack::build(BaselineKind::Rtn, &stats, Some(act.clone()), None, None, 2)
        .with_lvm_skips();
    let mut stamped_act = act;
    stamped_act.hp_tokens = 8;
    let svd_stamp =
        QuantStack::build(BaselineKind::SvdQuant, &stats, Some(stamped_act), None, None, 2)
            .with_lvm_skips()
            .with_stamp(QuantStack::lvm_stamp(8, 8));

    let prompt = PromptSet::coco().prompts[0];
    let z_fp = dit.sample(&FpHook, prompt, 9);
    let z_rtn = dit.sample(&QuantHook::new(&rtn), prompt, 9);
    let z_ss = dit.sample(&QuantHook::new(&svd_stamp), prompt, 9);
    let s_rtn = sqnr(&z_fp, &z_rtn);
    let s_ss = sqnr(&z_fp, &z_ss);
    assert!(s_ss > s_rtn, "svdquant+stamp {s_ss} !> rtn {s_rtn}");
}

/// Config file → stack assembly → evaluation (the CLI's serve path).
#[test]
fn config_driven_stack() {
    let toml = r#"
[model]
kind = "gpt"
variant = "tiny"
seq_len = 128

[quant]
baseline = "smoothquant"
stamp = true
transform = "wht"
act_bits = 4
hp_tokens = 8
"#;
    let cfg = RunConfig::from_toml_str(toml).unwrap();
    assert_eq!(cfg.quant.baseline_kind().unwrap(), Some(BaselineKind::SmoothQuant));
    assert_eq!(cfg.quant.seq_transform().unwrap(), SeqTransformKind::Wht);
    let act = cfg.quant.act_cfg();
    assert_eq!(act.bits, 4);
    assert_eq!(act.hp_tokens, 8);

    // Assemble and run it.
    let gpt = Gpt::new(GptConfig::tiny(), 8);
    let corpus = Corpus::generate(2_000, 8);
    let stats = calibrate_gpt(&gpt, &corpus, 128);
    let mut stack = QuantStack::build(
        cfg.quant.baseline_kind().unwrap().unwrap(),
        &stats,
        Some(act),
        None,
        None,
        3,
    );
    if cfg.quant.stamp {
        stack = stack.with_stamp(QuantStack::llm_stamp(cfg.quant.seq_transform().unwrap()));
    }
    let seqs_all = corpus.sequences(128);
    let seqs: Vec<&[u32]> = seqs_all.iter().take(1).cloned().collect();
    let p = perplexity(&gpt, &QuantHook::new(&stack), &seqs);
    assert!(p.is_finite() && p > 1.0);
}

/// Coordinator serving a real STaMP-quantized executor: responses must be
/// numerically identical to calling the quantizer inline (determinism
/// across the threaded path) and batching must kick in.
#[test]
fn serve_quantized_deterministic() {
    let s = 64;
    let stamp = Arc::new(Stamp::new(
        StampConfig { hp_tokens: 8, ..Default::default() },
        s,
    ));
    let stamp2 = stamp.clone();
    let executor: Arc<dyn Executor> = Arc::new(move |_v: &str, inputs: &[&Tensor]| {
        Ok(inputs.iter().map(|x| stamp2.quantize_dequantize(x)).collect::<Vec<_>>())
    });
    let spec = ServeSpec { workers: 3, max_batch: 4, max_wait_us: 500, queue_depth: 32 };
    let server = Server::start(&spec, &["stamp-a4"], executor);
    let handle = server.handle();

    let gen = ActivationGenerator::new(ActivationSpec {
        outlier_channels: 0,
        sink_scale: 0.0,
        ..ActivationSpec::llm(s, 32)
    });
    let inputs: Vec<Tensor> = (0..24).map(|i| gen.sample(i)).collect();
    let rxs: Vec<_> = inputs.iter().map(|x| handle.submit("stamp-a4", x.clone()).1).collect();
    for (x, rx) in inputs.iter().zip(&rxs) {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let out = resp.output.unwrap();
        let want = stamp.quantize_dequantize(x);
        assert!(out.max_abs_diff(&want) < 1e-6, "served result differs from inline");
    }
    let vm = handle.metrics.variant("stamp-a4");
    assert!(vm.mean_batch_size() > 1.0, "batching never engaged");
    server.shutdown();
}

/// Property: across random stacks, quantized logits stay finite and the
/// FP stack is always exact — the hook layer never corrupts numerics.
#[test]
fn property_hook_numerics() {
    let gpt = Gpt::new(GptConfig::tiny(), 10);
    let corpus = Corpus::generate(2_000, 10);
    let stats = calibrate_gpt(&gpt, &corpus, 64);
    stamp::testkit::check(
        "hook-numerics",
        12,
        0xABCD,
        |g| {
            let kind = match g.usize_in(0, 4) {
                0 => BaselineKind::Rtn,
                1 => BaselineKind::SmoothQuant,
                2 => BaselineKind::QuaRot,
                3 => BaselineKind::FlatQuant,
                _ => BaselineKind::SvdQuant,
            };
            let bits = g.usize_in(2, 8) as u32;
            let hp = g.usize_in(0, 16);
            let stamp = g.usize_in(0, 1) == 1;
            (kind, bits, hp, stamp)
        },
        |&(kind, bits, hp, stamp)| {
            let act = ActQuantCfg {
                bits,
                hp_tokens: hp,
                hp_bits: 8,
                granularity: stamp::quant::Granularity::PerToken,
                range_shrink: 1.0,
            };
            let mut s = QuantStack::build(kind, &stats, Some(act), None, None, 11);
            if stamp {
                s = s.with_stamp(QuantStack::llm_stamp(SeqTransformKind::HaarDwt));
            }
            let tokens: Vec<u32> = (0..64).map(|i| ((i * 3) % 70) as u32).collect();
            let logits = gpt.logits_hooked(&QuantHook::new(&s), &tokens);
            if !logits.all_finite() {
                return Err(format!("non-finite logits for {kind:?} b={bits} hp={hp} stamp={stamp}"));
            }
            Ok(())
        },
    );
}
