//! Sliding-window KV eviction: the property harness for the memory-
//! management subsystem (DESIGN.md §13).
//!
//! Four invariants anchor eviction, each checked after *every* append of
//! a randomized schedule:
//!
//! 1. **Resident set** — always `sinks ∪ last-window` at block
//!    granularity, reconstructed here by an independent oracle.
//! 2. **Bit-identity** — gather after eviction equals the gather of an
//!    unevicted reference stream restricted to the resident set, bit for
//!    bit (evicting the past never re-represents what remains).
//! 3. **Bounded storage** — resident `storage_bits` never exceeds the
//!    sink + window budget, no matter how long the logical sequence grows.
//! 4. **Position bookkeeping** — `evicted()` is monotone and the
//!    `gap_row()/evicted()` mapping recovers exactly the oracle's absolute
//!    positions (absolute positions never regress).
//!
//! Plus the boundary cases the block math invites: non-block-aligned
//! sinks (the straddling block must be retained whole) and an fp32 tail
//! adjacent to the eviction frontier (a token can never be evicted before
//! it has been flushed) — and the long-sequence acceptance run: a
//! windowed stream decodes to 4× the model's `max_seq` untruncated with
//! resident storage pinned under the budget.

use stamp::kvcache::{EvictionPolicy, KvCache, KvCacheConfig, KvStream};
use stamp::model::{FpHook, Gpt, GptConfig};
use stamp::stamp::SeqTransformKind;
use stamp::tensor::Tensor;
use stamp::testkit;

/// Independent oracle for the resident set: position `p` of a `len`-token
/// stream survives iff its block holds a sink token, is not yet
/// finalized (the fp32 tail), or still overlaps the last `window` tokens.
fn expected_resident(len: usize, sink_tokens: usize, window: usize, block: usize) -> Vec<usize> {
    let sink_span = sink_tokens.div_ceil(block) * block;
    let finalized = (len / block) * block;
    (0..len)
        .filter(|&p| {
            let b_start = (p / block) * block;
            let b_end = b_start + block;
            b_start < sink_span || b_end > finalized || b_end + window > len
        })
        .collect()
}

#[derive(Debug)]
struct EvictCase {
    d: usize,
    block: usize,
    sink: usize,
    window: usize,
    packed: bool,
    lp: u32,
    transform: SeqTransformKind,
    chunks: Vec<usize>,
    seed: u64,
}

#[test]
fn property_resident_set_bit_identity_storage_and_positions() {
    testkit::check(
        "kv-eviction-invariants",
        24,
        0xE71C7,
        |g| {
            let block = g.pow2_in(2, 16);
            let n_chunks = g.usize_in(1, 24);
            EvictCase {
                d: g.usize_in(1, 12),
                block,
                sink: g.usize_in(0, 2 * block + 3),
                window: block + g.usize_in(0, 40),
                packed: g.usize_in(0, 1) == 1,
                lp: if g.usize_in(0, 1) == 0 { 4 } else { 8 },
                transform: match g.usize_in(0, 2) {
                    0 => SeqTransformKind::Identity,
                    1 => SeqTransformKind::HaarDwt,
                    _ => SeqTransformKind::Dct,
                },
                chunks: (0..n_chunks).map(|_| g.usize_in(1, 7)).collect(),
                seed: g.rng.next_u64(),
            }
        },
        |c| {
            let cfg = if c.packed {
                // sinks ≤ hp_tokens boundary rule: pin the hp prefix to
                // the sink prefix, exactly the two-level mapping.
                KvCacheConfig::two_level(c.sink, 8, c.lp, c.block).with_transform(c.transform)
            } else {
                KvCacheConfig { block: c.block, ..KvCacheConfig::fp32() }
            };
            let mut st = KvStream::new(cfg.clone().with_window(c.sink, c.window));
            let mut reference = KvStream::new(cfg);
            let total: usize = c.chunks.iter().sum();
            let x = Tensor::randn(&[total, c.d], c.seed);
            let sink_span = c.sink.div_ceil(c.block) * c.block;
            let worst_row = if c.packed {
                (8usize.max(c.lp as usize) * c.d + 32).max(32 * c.d)
            } else {
                32 * c.d
            };
            let budget = (sink_span + c.window + c.block) * worst_row;
            let mut off = 0usize;
            let mut prev_evicted = 0usize;
            for &n in &c.chunks {
                st.append(&x.slice_rows(off, off + n));
                reference.append(&x.slice_rows(off, off + n));
                off += n;
                let expected = expected_resident(off, c.sink, c.window, c.block);
                // (1) + (4): the gap mapping reproduces the oracle's
                // absolute positions exactly.
                if st.resident_len() != expected.len() {
                    return Err(format!(
                        "len {off}: resident {} != oracle {}",
                        st.resident_len(),
                        expected.len()
                    ));
                }
                let mapped: Vec<usize> = (0..st.resident_len())
                    .map(|r| if r < st.gap_row() { r } else { r + st.evicted() })
                    .collect();
                if mapped != expected {
                    return Err(format!("len {off}: positions {mapped:?} != {expected:?}"));
                }
                if st.evicted() < prev_evicted {
                    return Err(format!("len {off}: evicted() regressed"));
                }
                prev_evicted = st.evicted();
                // (2): bit-identity against the unevicted reference,
                // restricted to the resident set.
                let g = st.gather();
                let r = reference.gather();
                for (row, &abs) in expected.iter().enumerate() {
                    if g.row(row) != r.row(abs) {
                        return Err(format!("len {off}: resident row {row} (abs {abs}) diverged"));
                    }
                }
                // (3): resident residency + storage bounded by the
                // sink + window budget at every instant.
                if st.resident_len() >= sink_span + c.window + c.block {
                    return Err(format!("len {off}: residency {} unbounded", st.resident_len()));
                }
                if st.storage_bits() > budget {
                    return Err(format!(
                        "len {off}: storage {} exceeds budget {budget}",
                        st.storage_bits()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn non_block_aligned_sinks_retain_the_straddling_block_whole() {
    // sink_tokens 12 over 8-token blocks: the sink span rounds up to 16 —
    // the block holding tokens 12..16 straddles the boundary and must
    // never be evicted, while block [16,24) evicts on schedule.
    let (block, sink, window) = (8usize, 12usize, 8usize);
    let x = Tensor::randn(&[96, 6], 41);
    let mut st = KvStream::new(KvCacheConfig::two_level(12, 8, 4, block).with_window(sink, window));
    let mut reference = KvStream::new(KvCacheConfig::two_level(12, 8, 4, block));
    for i in 0..96 {
        st.append(&x.slice_rows(i, i + 1));
        reference.append(&x.slice_rows(i, i + 1));
        // Tokens 0..16 stay resident at every length once appended.
        let keep = 16.min(st.resident_len());
        let g = st.gather();
        let r = reference.gather();
        for p in 0..keep.min(i + 1) {
            assert_eq!(g.row(p), r.row(p), "len {}: sink-span row {p} must stay", i + 1);
        }
    }
    assert_eq!(st.gap_row(), 16, "gap sits at the block-rounded sink span");
    assert!(st.evicted() > 0);
    // The straddle rows 12..16 are hp-boundary rows of a *retained* block:
    // stored at lp (outside hp_tokens = 12) but never evicted.
    let expected = expected_resident(96, sink, window, block);
    assert_eq!(st.resident_len(), expected.len());
    assert!(expected.contains(&12) && expected.contains(&15));
}

#[test]
fn fp32_tail_is_never_evicted_before_flush() {
    // window == block keeps the recency region minimal: the tail sits
    // directly against the eviction frontier, and every tail row must
    // still read back bit-exactly (only *finalized* blocks evict).
    let (block, window) = (4usize, 4usize);
    for packed in [false, true] {
        let base = if packed {
            KvCacheConfig::two_level(0, 8, 8, block)
        } else {
            KvCacheConfig { block, ..KvCacheConfig::fp32() }
        };
        let mut st = KvStream::new(base.with_window(0, window));
        let x = Tensor::randn(&[43, 5], 43);
        for i in 0..43 {
            st.append(&x.slice_rows(i, i + 1));
            let tail = (i + 1) % block;
            let g = st.gather();
            for t in 0..tail {
                let row = g.rows() - tail + t;
                let abs = i + 1 - tail + t;
                assert_eq!(g.row(row), x.row(abs), "len {}: tail row {t} must be exact", i + 1);
            }
        }
        // 43 = 10 blocks + 3 tail: blocks [0,36) are out (end + 4 ≤ 43
        // holds through end 36 → eviction stops at block [36,40)).
        assert_eq!(st.evicted(), 36, "packed={packed}");
        assert_eq!(st.resident_len(), 7, "packed={packed}");
    }
}

#[test]
fn windowed_decode_reaches_4x_max_seq_with_bounded_resident_storage() {
    // Acceptance: a windowed stream decodes to ≥ 4× the model's max_seq
    // without truncation, and the resident cache footprint stays pinned
    // under the sink + window budget the whole way.
    let gpt = Gpt::new(GptConfig::tiny(), 61);
    let kv = KvCacheConfig::two_level(16, 8, 4, 8).with_window(16, 48);
    assert_eq!(kv.eviction, EvictionPolicy::SlidingWindow { sink_tokens: 16, window: 48 });
    let bound = kv.resident_bound().unwrap();
    assert!(bound <= gpt.cfg.max_seq);
    let mut cache = KvCache::new(gpt.cfg.n_layers, kv);
    let prompt: Vec<u32> = (0..8).map(|i| ((i * 11 + 2) % 70) as u32).collect();
    let n_new = 4 * gpt.cfg.max_seq;
    let out = gpt.generate_greedy(&FpHook, &prompt, n_new, &mut cache);
    assert_eq!(out.len(), n_new);
    assert!(cache.len() >= 4 * gpt.cfg.max_seq, "logical length passes 4× max_seq untruncated");
    assert!(cache.resident_len() < bound);
    // Budget: every resident row costs at most max(hp,lp)·d + 32 bits
    // packed, or 32·d in the fp32 tail — per stream, 2 streams per layer.
    let d = gpt.cfg.d_model;
    let worst_row = (8 * d + 32).max(32 * d);
    assert!(cache.storage_bits() <= gpt.cfg.n_layers * 2 * bound * worst_row);
    // Steady state: decoding further cannot grow residency or storage
    // past the same budget.
    let mut next = *out.last().unwrap();
    for _ in 0..64 {
        let logits = gpt.decode_step(&FpHook, next, &mut cache);
        next = logits.row(0).iter().enumerate().fold(0u32, |b, (i, &v)| {
            if v > logits.at(0, b as usize) {
                i as u32
            } else {
                b
            }
        });
        assert!(cache.resident_len() < bound);
        assert!(cache.storage_bits() <= gpt.cfg.n_layers * 2 * bound * worst_row);
    }
    // The quantized windowed cache still beats fp32 on resident bits.
    assert!(cache.average_storage_bits() < 32.0);
}
