//! Autoregressive decode subsystem integration: the parity harness, the
//! KV-cache storage accounting, quantified drift, and coordinator-served
//! generation.
//!
//! Three invariants anchor the subsystem:
//!
//! 1. **fp32-cache parity** — greedy decode with an unquantized cache is
//!    *bit-identical* to `Gpt::logits_hooked` on the same token prefix at
//!    any thread count (every kernel on the decode path is row-wise; CI
//!    runs this file under both `STAMP_THREADS=1` and the default).
//! 2. **Storage accounting** — `KvCache::storage_bits` reproduces the
//!    Appendix-C accounting for the configured two-level allocation, and
//!    the measured average sits within one bit of `lp_bits` once
//!    `s ≫ hp_tokens`.
//! 3. **Bounded drift** — quantizing the cache perturbs decode logits
//!    measurably but boundedly (logit SQNR + next-token NLL drift are the
//!    numbers a deployment trades against the memory win).

use stamp::decode::{DecodeEngine, GenRequest, Sampling};
use stamp::kvcache::{KvCache, KvCacheConfig, KvStream};
use stamp::model::{softmax_rows, FpHook, Gpt, GptConfig};
use stamp::quant::{quantize_dequantize_rows, BitAllocation, Granularity};
use stamp::stamp::SeqTransformKind;
use stamp::stats::sqnr;
use stamp::tensor::Tensor;
use stamp::testkit;
use std::sync::Arc;
use std::time::Duration;

fn prefix_tokens(n: usize) -> Vec<u32> {
    (0..n).map(|i| ((i * 7 + 3) % 70) as u32).collect()
}

/// Step-by-step decode over `tokens` with an fp32 cache; every step's
/// logits row must equal the full-sequence forward's row bit-for-bit.
fn assert_parity(gpt: &Gpt, tokens: &[u32]) {
    let full = gpt.logits_hooked(&FpHook, tokens);
    let mut cache = KvCache::fp32(gpt.cfg.n_layers);
    let first = gpt.prefill(&FpHook, &tokens[..1], &mut cache);
    assert_eq!(first.row(0), full.row(0), "prefill row 0");
    for (t, &tok) in tokens.iter().enumerate().skip(1) {
        let l = gpt.decode_step(&FpHook, tok, &mut cache);
        assert_eq!(l.row(0), full.row(t), "step {t} logits must be bit-identical");
    }
}

#[test]
fn decode_fp32_cache_parity_bit_identical() {
    let gpt = Gpt::new(GptConfig::tiny(), 3);
    let tokens = prefix_tokens(24);
    assert_parity(&gpt, &tokens);
    // Forced-serial kernels must reproduce the same rows — decode parity
    // holds at any thread count (CI re-runs the whole file under
    // STAMP_THREADS=1 as well).
    stamp::parallel::set_kernel_serial(true);
    assert_parity(&gpt, &tokens);
    stamp::parallel::set_kernel_serial(false);
}

#[test]
fn chunked_prefill_matches_one_shot() {
    let gpt = Gpt::new(GptConfig::tiny(), 4);
    let tokens = prefix_tokens(20);
    let full = gpt.logits_hooked(&FpHook, &tokens);
    let mut cache = KvCache::fp32(gpt.cfg.n_layers);
    let a = gpt.prefill(&FpHook, &tokens[..13], &mut cache);
    let b = gpt.prefill(&FpHook, &tokens[13..], &mut cache);
    for t in 0..13 {
        assert_eq!(a.row(t), full.row(t), "chunk-1 row {t}");
    }
    for t in 13..20 {
        assert_eq!(b.row(t - 13), full.row(t), "chunk-2 row {t}");
    }
}

#[test]
fn packed_cache_storage_matches_appendix_c_accounting() {
    // 512 tokens, 8 sink tokens, 16-token blocks: every token's cost is
    // payload bits·d plus one fp16 scale + fp16 zero (32 bits) per row
    // (per-token granularity) — the Appendix-C accounting.
    let (s, d, block, hp) = (512usize, 64usize, 16usize, 8usize);
    let mut st = KvStream::new(KvCacheConfig::two_level(hp, 8, 4, block));
    st.append(&Tensor::randn(&[s, d], 7));
    let flushed = (s / block) * block;
    let expect: usize = (0..s)
        .map(|i| {
            if i < flushed {
                let bits = if i < hp { 8 } else { 4 };
                bits * d + 32
            } else {
                32 * d
            }
        })
        .sum();
    assert_eq!(st.storage_bits(), expect);
    // s ≫ hp_tokens ⇒ measured average within one bit of lp_bits.
    let avg = st.average_storage_bits();
    assert!(avg <= 4.0 + 1.0, "avg bits {avg} must be ≤ lp_bits + 1");
    assert!(avg > 4.0, "avg bits {avg} must include hp + parameter overhead");

    // Whole-cache accounting matches the sum of its streams.
    let gpt = Gpt::new(GptConfig::tiny(), 9);
    let mut cache = KvCache::new(gpt.cfg.n_layers, KvCacheConfig::two_level(8, 8, 4, 16));
    let _ = gpt.prefill(&FpHook, &prefix_tokens(64), &mut cache);
    let per_layer: usize = (0..gpt.cfg.n_layers)
        .map(|l| cache.layer(l).k.storage_bits() + cache.layer(l).v.storage_bits())
        .sum();
    assert_eq!(cache.storage_bits(), per_layer);
    assert!(cache.average_storage_bits() < 32.0, "quantized cache must beat fp32");
}

/// Teacher-forced decode logits (the last prompt row + one row per
/// continuation token) under a given cache policy.
fn forced_logits(gpt: &Gpt, cfg: KvCacheConfig, prompt: &[u32], cont: &[u32]) -> Tensor {
    let mut cache = KvCache::new(gpt.cfg.n_layers, cfg);
    let pre = gpt.prefill(&FpHook, prompt, &mut cache);
    let mut out = pre.slice_rows(pre.rows() - 1, pre.rows());
    for &t in &cont[..cont.len() - 1] {
        out = out.vcat(&gpt.decode_step(&FpHook, t, &mut cache));
    }
    out
}

/// Mean next-token negative log-likelihood of `cont` under those logits.
fn mean_nll(logits: &Tensor, cont: &[u32]) -> f64 {
    let mut probs = logits.clone();
    softmax_rows(&mut probs);
    let mut nll = 0.0f64;
    for (i, &t) in cont.iter().enumerate() {
        nll -= (probs.at(i, t as usize).max(1e-12) as f64).ln();
    }
    nll / cont.len() as f64
}

#[test]
fn packed_cache_drift_is_measurable_and_bounded() {
    let gpt = Gpt::new(GptConfig::tiny(), 5);
    let prompt = prefix_tokens(16);
    // Continuation chosen by the fp32 path, then teacher-forced through
    // both cache policies so the comparison isolates pure cache error.
    let mut c = KvCache::fp32(gpt.cfg.n_layers);
    let cont = gpt.generate_greedy(&FpHook, &prompt, 24, &mut c);

    let fp = forced_logits(&gpt, KvCacheConfig::fp32(), &prompt, &cont);
    let kv4 = forced_logits(
        &gpt,
        KvCacheConfig::two_level(4, 8, 4, 8).with_transform(SeqTransformKind::HaarDwt),
        &prompt,
        &cont,
    );
    assert!(kv4.all_finite());
    // Quantization must be visible…
    assert!(kv4.max_abs_diff(&fp) > 1e-4, "packed cache must perturb logits");
    // …but bounded: logit SQNR and next-token NLL drift stay sane.
    let s = sqnr(&fp, &kv4);
    assert!(s > 5.0, "decode logit SQNR {s} dB under packed KV4 cache");
    let d_nll = (mean_nll(&kv4, &cont) - mean_nll(&fp, &cont)).abs();
    assert!(d_nll < 1.0, "NLL drift {d_nll} nats under packed KV4 cache");
    println!("decode drift: logit SQNR {s:.1} dB, |ΔNLL| {d_nll:.4} nats");

    // An 8-bit cache must drift strictly less than the 4-bit one.
    let kv8 = forced_logits(&gpt, KvCacheConfig::two_level(0, 8, 8, 8), &prompt, &cont);
    assert!(sqnr(&fp, &kv8) > s, "KV8 must be closer to fp than KV4");
}

#[derive(Debug)]
struct RoundtripCase {
    s: usize,
    d: usize,
    block: usize,
    split: usize,
    seed: u64,
}

/// Satellite: append→gather round-trips bit-exactly against the one-shot
/// QDQ oracle when `lp_bits == hp_bits == 8` (identity blocks; per-token
/// QDQ is row-independent, so the incremental block partition must not
/// change a single bit), with the tail exact by construction.
#[test]
fn property_kv_append_gather_roundtrip_8bit() {
    testkit::check(
        "kv-append-gather-8bit",
        24,
        0xCAC4E,
        |g| RoundtripCase {
            s: g.usize_in(1, 80),
            d: g.usize_in(1, 24),
            block: g.pow2_in(2, 16),
            split: g.usize_in(0, 80),
            seed: g.rng.next_u64(),
        },
        |c| {
            let x = Tensor::randn(&[c.s, c.d], c.seed);
            let mut st = KvStream::new(KvCacheConfig::two_level(0, 8, 8, c.block));
            let split = c.split.min(c.s);
            st.append(&x.slice_rows(0, split));
            st.append(&x.slice_rows(split, c.s));
            let g = st.gather();
            let flushed = (c.s / c.block) * c.block;
            let want = quantize_dequantize_rows(
                &x,
                &BitAllocation::uniform(8),
                Granularity::PerToken,
            );
            for i in 0..c.s {
                let expect = if i < flushed { want.row(i) } else { x.row(i) };
                if g.row(i) != expect {
                    return Err(format!("row {i} diverged (flushed < {flushed})"));
                }
            }
            Ok(())
        },
    );
}

#[derive(Debug)]
struct IncrementalCase {
    s: usize,
    d: usize,
    block: usize,
    hp: usize,
    lp: u32,
    transform: SeqTransformKind,
    seed: u64,
}

/// Append granularity must never matter: token-by-token and one-shot
/// appends produce bit-identical gathers and storage accounting, for
/// every transform and bit mix.
#[test]
fn property_kv_incremental_equals_batch() {
    testkit::check(
        "kv-incremental-vs-batch",
        16,
        0xB10C,
        |g| IncrementalCase {
            s: g.usize_in(1, 60),
            d: g.usize_in(1, 20),
            block: g.pow2_in(2, 16),
            hp: g.usize_in(0, 40),
            lp: if g.usize_in(0, 1) == 0 { 4 } else { 8 },
            transform: match g.usize_in(0, 2) {
                0 => SeqTransformKind::Identity,
                1 => SeqTransformKind::HaarDwt,
                _ => SeqTransformKind::Dct,
            },
            seed: g.rng.next_u64(),
        },
        |c| {
            let x = Tensor::randn(&[c.s, c.d], c.seed);
            let mk = || {
                KvStream::new(
                    KvCacheConfig::two_level(c.hp, 8, c.lp, c.block)
                        .with_transform(c.transform),
                )
            };
            let mut batch = mk();
            batch.append(&x);
            let mut inc = mk();
            for i in 0..c.s {
                inc.append(&x.slice_rows(i, i + 1));
            }
            if inc.gather() != batch.gather() {
                return Err("gather differs between append granularities".into());
            }
            if inc.storage_bits() != batch.storage_bits() {
                return Err("storage_bits differs between append granularities".into());
            }
            Ok(())
        },
    );
}

/// Serial oracle for the batched engine: PR 3's per-request greedy loop.
fn serial_greedy(gpt: &Gpt, kv: &KvCacheConfig, prompt: &[u32], n_new: usize) -> Vec<u32> {
    let mut cache = KvCache::new(gpt.cfg.n_layers, kv.clone());
    gpt.generate_greedy(&FpHook, prompt, n_new, &mut cache)
}

#[test]
fn windowed_noop_decode_bit_identical_to_unwindowed() {
    // window ≥ seq_len ⇒ eviction never fires: teacher-forced decode
    // logits are bit-identical to the unwindowed decode paths, fp32 and
    // packed caches, threaded and forced-serial kernels (CI re-runs this
    // file under STAMP_THREADS=1 as well).
    let gpt = Gpt::new(GptConfig::tiny(), 51);
    let prompt = prefix_tokens(10);
    let mut c = KvCache::fp32(gpt.cfg.n_layers);
    let cont = gpt.generate_greedy(&FpHook, &prompt, 20, &mut c);
    for packed in [false, true] {
        let base =
            if packed { KvCacheConfig::two_level(8, 8, 4, 8) } else { KvCacheConfig::fp32() };
        let win = base.clone().with_window(8, 128);
        let a = forced_logits(&gpt, base, &prompt, &cont);
        let b = forced_logits(&gpt, win.clone(), &prompt, &cont);
        assert_eq!(a, b, "packed={packed}: windowed no-op must be bit-identical");
        stamp::parallel::set_kernel_serial(true);
        let b_serial = forced_logits(&gpt, win, &prompt, &cont);
        stamp::parallel::set_kernel_serial(false);
        assert_eq!(a, b_serial, "packed={packed}: serial-kernel run diverged");
    }
}

#[test]
fn windowed_noop_engine_matches_unwindowed_serial_and_batched() {
    // The same no-op guarantee through the engine: serial (decode_batch
    // 1) and fused stepping under a window config reproduce the
    // unwindowed serial oracle, fp32 and packed.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 53));
    let reqs = vec![
        GenRequest { prompt: prefix_tokens(5), n_new: 14 },
        GenRequest { prompt: prefix_tokens(12), n_new: 6 },
        GenRequest { prompt: prefix_tokens(3), n_new: 10 },
    ];
    for packed in [false, true] {
        let base =
            if packed { KvCacheConfig::two_level(4, 8, 4, 8) } else { KvCacheConfig::fp32() };
        let win = base.clone().with_window(4, 64);
        for decode_batch in [1usize, 8] {
            let mut engine = DecodeEngine::new(gpt.clone(), win.clone(), Sampling::Greedy)
                .with_decode_batch(decode_batch);
            let got = engine.run_fp(&reqs).unwrap();
            for (i, r) in reqs.iter().enumerate() {
                let want = serial_greedy(&gpt, &base, &r.prompt, r.n_new);
                assert_eq!(got[i].tokens, want, "packed={packed} b={decode_batch} stream {i}");
                assert!(!got[i].truncated);
            }
        }
    }
}

#[test]
fn coordinator_serves_long_generate_past_max_seq_under_window_policy() {
    use stamp::config::ServeSpec;
    use stamp::coordinator::Server;
    use stamp::runtime::NativeExecutor;

    // Satellite: a generate request whose prompt + budget exceeds the
    // model's max_seq completes un-truncated end to end once the variant
    // carries a window policy — and the pre-eviction recoverable path
    // still rejects the same request on a bounded variant.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 57));
    let win = KvCacheConfig::two_level(8, 8, 4, 8).with_window(8, 48);
    let exec = NativeExecutor::new()
        .with_gpt_generate("gen-win", gpt.clone(), None, win, 400)
        .with_gpt_generate("gen-bounded", gpt.clone(), None, KvCacheConfig::fp32(), 400);
    let spec = ServeSpec { workers: 2, max_batch: 4, max_wait_us: 500, queue_depth: 16 };
    let server = Server::start(&spec, &["gen-win", "gen-bounded"], Arc::new(exec));
    let handle = server.handle();
    // [n_new = 300, 8-token prompt]: 308 > max_seq 256.
    let mut row = vec![300.0];
    row.extend(prefix_tokens(8).iter().map(|&t| t as f32));
    let input = Tensor::from_vec(&[1, row.len()], row);
    let resp = handle.call("gen-win", input.clone(), Duration::from_secs(60)).unwrap();
    let out = resp.output.unwrap();
    assert_eq!(out.shape(), &[1, 300], "windowed variant serves the full budget");
    for &v in out.data() {
        assert!(v.fract() == 0.0 && (v as usize) < gpt.cfg.vocab_size, "token {v}");
    }
    let resp = handle.call("gen-bounded", input, Duration::from_secs(60)).unwrap();
    let err = resp.output.unwrap_err();
    assert!(err.contains("exceeds max_seq"), "{err}");
    server.shutdown();
}

#[test]
fn batched_decode_bit_identical_to_serial_any_thread_count() {
    // The tentpole invariant: with an fp32 cache, every stream of a fused
    // batch reproduces its serial `generate_greedy` run bit-for-bit —
    // mixed prompt lengths, mixed budgets (mid-run retirement), any
    // decode_batch chunking, threaded and forced-serial kernels.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 21));
    let reqs = vec![
        GenRequest { prompt: prefix_tokens(5), n_new: 20 },
        GenRequest { prompt: prefix_tokens(11), n_new: 3 },
        GenRequest { prompt: vec![7, 1, 42], n_new: 12 },
        GenRequest { prompt: prefix_tokens(17), n_new: 1 },
        GenRequest { prompt: prefix_tokens(2), n_new: 16 },
    ];
    let kv = KvCacheConfig::fp32();
    for decode_batch in [1usize, 3, 8] {
        let mut engine = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy)
            .with_decode_batch(decode_batch);
        let threaded = engine.run_fp(&reqs).unwrap();
        stamp::parallel::set_kernel_serial(true);
        let serial_kernels = engine.run_fp(&reqs).unwrap();
        stamp::parallel::set_kernel_serial(false);
        for (i, r) in reqs.iter().enumerate() {
            let want = serial_greedy(&gpt, &kv, &r.prompt, r.n_new);
            assert_eq!(threaded[i].tokens, want, "decode_batch {decode_batch} stream {i}");
            assert!(!threaded[i].truncated);
            assert_eq!(
                serial_kernels[i], threaded[i],
                "decode_batch {decode_batch} stream {i} thread-count invariance"
            );
        }
    }
}

#[test]
fn batched_decode_with_packed_cache_matches_serial_packed_decode() {
    // Streams never share cache state, and the fused linears are
    // row-wise, so even a *quantized* per-stream cache keeps batched ==
    // serial exactly; the cache policy's drift vs fp32 stays the
    // separately-pinned envelope (`packed_cache_drift_is_measurable_and_bounded`).
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 23));
    let kv = KvCacheConfig::two_level(4, 8, 4, 8).with_transform(SeqTransformKind::HaarDwt);
    let reqs = vec![
        GenRequest { prompt: prefix_tokens(9), n_new: 14 },
        GenRequest { prompt: prefix_tokens(3), n_new: 6 },
        GenRequest { prompt: prefix_tokens(13), n_new: 10 },
    ];
    let mut engine =
        DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy).with_decode_batch(2);
    let got = engine.run_fp(&reqs).unwrap();
    for (i, r) in reqs.iter().enumerate() {
        let want = serial_greedy(&gpt, &kv, &r.prompt, r.n_new);
        assert_eq!(got[i].tokens, want, "packed-cache stream {i}");
    }
}

#[derive(Debug)]
struct BatchCase {
    n_streams: usize,
    prompts: Vec<usize>,
    budgets: Vec<usize>,
    decode_batch: usize,
    packed: bool,
    /// Sliding-window config for this composition (0 = no eviction).
    /// Generated ≥ any stream's prompt + budget, so eviction is a no-op
    /// and the unwindowed serial oracle must still match bit-for-bit.
    window: usize,
    seed: u64,
}

/// Satellite: batched-vs-serial parity as a property over random batch
/// compositions — ragged prompts, ragged budgets (so slots retire at
/// different steps), random fusion width, fp32 and packed caches, with
/// and without a (no-op sized) per-composition window config.
#[test]
fn property_batched_decode_equals_serial_per_stream() {
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 25));
    testkit::check(
        "batched-vs-serial-decode",
        10,
        0xBA7C5,
        |g| {
            let n_streams = g.usize_in(1, 5);
            BatchCase {
                n_streams,
                prompts: (0..n_streams).map(|_| g.usize_in(1, 24)).collect(),
                budgets: (0..n_streams).map(|_| g.usize_in(0, 12)).collect(),
                decode_batch: g.usize_in(1, 4),
                packed: g.usize_in(0, 1) == 1,
                // prompts ≤ 24 and budgets ≤ 12 keep every logical length
                // ≤ 36 < 40 ≤ window: eviction can never fire.
                window: if g.usize_in(0, 2) == 0 { 0 } else { 40 + g.usize_in(0, 80) },
                seed: g.rng.next_u64(),
            }
        },
        |c| {
            let base = if c.packed {
                KvCacheConfig::two_level(4, 8, 4, 8)
            } else {
                KvCacheConfig::fp32()
            };
            let kv = if c.window > 0 { base.clone().with_window(4, c.window) } else { base.clone() };
            let reqs: Vec<GenRequest> = (0..c.n_streams)
                .map(|i| GenRequest {
                    prompt: (0..c.prompts[i])
                        .map(|j| ((c.seed as usize + i * 13 + j * 7) % 70) as u32)
                        .collect(),
                    n_new: c.budgets[i],
                })
                .collect();
            let mut engine = DecodeEngine::new(gpt.clone(), kv, Sampling::Greedy)
                .with_decode_batch(c.decode_batch);
            let got = engine.run_fp(&reqs).map_err(|e| e.to_string())?;
            for (i, r) in reqs.iter().enumerate() {
                // The oracle always runs *unwindowed*: a no-op-sized
                // window must change nothing, bit for bit.
                let want = serial_greedy(&gpt, &base, &r.prompt, r.n_new);
                if got[i].tokens != want {
                    return Err(format!("stream {i}: batched {:?} != serial {want:?}", got[i].tokens));
                }
                if got[i].truncated {
                    return Err(format!("stream {i}: unexpected truncation"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn generate_batch_through_coordinator_matches_serial() {
    use stamp::config::ServeSpec;
    use stamp::coordinator::Server;
    use stamp::runtime::NativeExecutor;

    // End-to-end: concurrent generate calls batched by the coordinator
    // are fused by the executor into one engine run — and still come back
    // request-for-request identical to serial decode.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 27));
    let exec = NativeExecutor::new().with_gpt_generate(
        "gen-batched",
        gpt.clone(),
        None,
        KvCacheConfig::fp32(),
        32,
    );
    let spec = ServeSpec { workers: 1, max_batch: 4, max_wait_us: 20_000, queue_depth: 16 };
    let server = Server::start(&spec, &["gen-batched"], Arc::new(exec));
    let handle = server.handle();
    let prompts: Vec<Vec<u32>> = vec![prefix_tokens(4), prefix_tokens(9), prefix_tokens(2)];
    let n_new = [10usize, 5, 8];
    // Submit all three before collecting, so the batcher can coalesce
    // them into one fused engine run.
    let mut pending = Vec::new();
    for (p, &n) in prompts.iter().zip(&n_new) {
        let mut row = vec![n as f32];
        row.extend(p.iter().map(|&t| t as f32));
        let input = Tensor::from_vec(&[1, row.len()], row);
        let (_, rx) = handle.submit("gen-batched", input);
        pending.push(rx);
    }
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let out = resp.output.unwrap();
        let want = serial_greedy(&gpt, &KvCacheConfig::fp32(), &prompts[i], n_new[i]);
        assert_eq!(out.shape(), &[1, n_new[i]], "request {i}");
        for (j, &w) in want.iter().enumerate() {
            assert_eq!(out.at(0, j), w as f32, "request {i} token {j}");
        }
    }
    server.shutdown();
}

#[test]
fn engine_truncation_rides_the_kv_capacity_error() {
    // The recoverable KvStream bound and the engine's truncation flag are
    // two views of the same condition: a stream that outgrows its cache
    // retires early with the generated prefix intact, and its batch-mates
    // never notice.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 29));
    let kv = KvCacheConfig::fp32().with_max_seq(10);
    let reqs = vec![
        GenRequest { prompt: prefix_tokens(7), n_new: 24 },
        GenRequest { prompt: prefix_tokens(3), n_new: 5 },
    ];
    let mut engine = DecodeEngine::new(gpt.clone(), kv, Sampling::Greedy);
    let got = engine.run_fp(&reqs).unwrap();
    // Stream 0: prefill 7 + 3 appends reach cap 10 → 4 tokens out.
    assert!(got[0].truncated);
    assert_eq!(got[0].tokens.len(), 4);
    let serial = serial_greedy(&gpt, &KvCacheConfig::fp32(), &reqs[0].prompt, 24);
    assert_eq!(got[0].tokens[..], serial[..4], "truncated prefix still matches serial");
    // Stream 1 is untouched by its neighbor's retirement.
    assert!(!got[1].truncated);
    assert_eq!(got[1].tokens, serial_greedy(&gpt, &KvCacheConfig::fp32(), &reqs[1].prompt, 5));
}

#[test]
fn speculative_truncation_matches_plain_at_the_capacity_wall() {
    use stamp::decode::{DraftKind, SpecConfig};
    // Satellite: the capacity frontier under speculation. A rollback (or
    // depth cap) landing exactly on `max_seq` must leave the engine's
    // truncation accounting identical to the plain path — same truncated
    // flags, same token counts, no `n_new` overshoot and no spurious
    // `truncated` on a stream that merely *filled* its cache while
    // retiring on budget.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 29));
    let kv = KvCacheConfig::fp32().with_max_seq(10);
    let reqs = vec![
        // Outgrows the cache: retires truncated with exactly 4 tokens
        // (the `engine_truncation_rides_the_kv_capacity_error` workload).
        GenRequest { prompt: prefix_tokens(7), n_new: 24 },
        // Budget and capacity land on the same step: prefill 6 + four
        // appends fill the cache exactly as the fifth token retires the
        // stream on budget — must NOT be flagged truncated.
        GenRequest { prompt: prefix_tokens(6), n_new: 5 },
        // Comfortably inside both bounds.
        GenRequest { prompt: prefix_tokens(3), n_new: 5 },
    ];
    let mut plain = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy);
    let want = plain.run_fp(&reqs).unwrap();
    assert!(want[0].truncated && want[0].tokens.len() == 4);
    assert!(!want[1].truncated && want[1].tokens.len() == 5);
    assert!(!want[2].truncated && want[2].tokens.len() == 5);
    for draft in [DraftKind::Ngram, DraftKind::Packed] {
        for k in [1usize, 2, 4, 8] {
            let mut eng = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy)
                .with_speculative(SpecConfig { draft, k });
            let got = eng.run_fp(&reqs).unwrap();
            assert_eq!(got, want, "draft {draft:?} k={k}");
        }
    }
}

#[test]
fn speculative_capacity_frontier_sweep_matches_plain() {
    use stamp::decode::{DraftKind, SpecConfig};
    // The same frontier swept across cache sizes and policies: wherever
    // the wall sits relative to block boundaries and the fp32 tail, the
    // speculative engine's `StreamResult`s (tokens *and* flags) equal the
    // plain engine's exactly.
    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 31));
    let caps: Vec<KvCacheConfig> = vec![
        KvCacheConfig::fp32().with_max_seq(8),
        KvCacheConfig::fp32().with_max_seq(12),
        KvCacheConfig::two_level(4, 8, 4, 8).with_max_seq(16),
        KvCacheConfig::two_level(4, 8, 4, 8).with_max_seq(24),
    ];
    for kv in caps {
        let reqs = vec![
            GenRequest { prompt: prefix_tokens(7), n_new: 24 },
            GenRequest { prompt: prefix_tokens(3), n_new: 4 },
        ];
        let mut plain =
            DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy).with_decode_batch(2);
        let want = plain.run_fp(&reqs).unwrap();
        // Prefill 7 then one token per position up to the wall:
        // 1 + (max_seq − 7) tokens, truncated.
        assert!(want[0].truncated, "{kv:?}");
        assert_eq!(want[0].tokens.len(), 1 + (kv.max_seq.unwrap() - 7), "{kv:?}");
        assert!(!want[1].truncated, "{kv:?}");
        for draft in [DraftKind::Ngram, DraftKind::Packed] {
            for k in [1usize, 3, 6] {
                let mut eng = DecodeEngine::new(gpt.clone(), kv.clone(), Sampling::Greedy)
                    .with_decode_batch(2)
                    .with_speculative(SpecConfig { draft, k });
                let got = eng.run_fp(&reqs).unwrap();
                assert_eq!(got, want, "{kv:?} draft {draft:?} k={k}");
            }
        }
    }
}

#[test]
fn generate_serves_through_coordinator_with_packed_kv() {
    use stamp::config::ServeSpec;
    use stamp::coordinator::Server;
    use stamp::runtime::NativeExecutor;

    let gpt = Arc::new(Gpt::new(GptConfig::tiny(), 11));
    let kv = KvCacheConfig::two_level(4, 8, 4, 8).with_transform(SeqTransformKind::HaarDwt);
    let exec = NativeExecutor::new().with_gpt_generate("gen-kv4", gpt.clone(), None, kv, 32);
    let spec = ServeSpec { workers: 2, max_batch: 4, max_wait_us: 500, queue_depth: 16 };
    let server = Server::start(&spec, &["gen-kv4"], Arc::new(exec));
    let handle = server.handle();
    // [n_new = 12, prompt…]
    let input = Tensor::from_vec(&[1, 5], vec![12.0, 3.0, 17.0, 41.0, 5.0]);
    let a = handle.call("gen-kv4", input.clone(), Duration::from_secs(30)).unwrap();
    let a = a.output.unwrap();
    assert_eq!(a.shape(), &[1, 12]);
    for &v in a.data() {
        assert!(v.fract() == 0.0 && (v as usize) < gpt.cfg.vocab_size, "token {v}");
    }
    // Generation is deterministic: the same request yields the same ids.
    let b = handle.call("gen-kv4", input, Duration::from_secs(30)).unwrap();
    assert_eq!(a, b.output.unwrap());
    server.shutdown();
}
