//! API-surface stub for the `xla` PJRT bindings.
//!
//! The STaMP reproduction's `pjrt` feature needs an `xla` crate to compile
//! against, but build environments for this repo are offline and most have
//! no XLA toolchain. This stub keeps `cargo build --features pjrt`
//! compiling everywhere: it mirrors exactly the slice of the real crate's
//! API that `stamp::runtime::engine` touches, and every entry point that
//! would talk to a device returns [`Error`] ("PJRT runtime not linked").
//!
//! To run against real hardware, point Cargo at a real `xla` crate:
//!
//! ```toml
//! [patch.crates-io]        # or a [patch."…"] for the vendored path
//! xla = { path = "/path/to/real/xla-rs" }
//! ```
//!
//! Data-only types ([`Literal`], [`ArrayShape`]) are functional so callers
//! can build inputs before the first device call fails cleanly.

use std::borrow::Borrow;
use std::fmt;

/// Error type matching the shape the engine consumes (`Display` only).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn not_linked(what: &str) -> Error {
    Error(format!(
        "{what}: xla stub — PJRT runtime not linked in this build; \
         patch the `xla` dependency with a real crate to use hardware"
    ))
}

/// A host-side literal: flat f32 data plus dimensions.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from an f32 slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Decompose a tuple literal. The stub never produces tuples, so this
    /// only ever reports the missing runtime.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(not_linked("Literal::to_tuple"))
    }

    /// Shape accessor.
    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Typed element extraction; unavailable without the real runtime's
    /// layout handling.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(not_linked("Literal::to_vec"))
    }
}

/// Dimensions of an array-shaped literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO-text artifact. Requires the real parser.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(not_linked("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a module proto.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(not_linked("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable bound to a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(not_linked("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client. [`PjRtClient::cpu`] fails in the stub, so downstream
/// code observes "PJRT unavailable" before any other call can happen.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(not_linked("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(not_linked("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_are_functional() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn device_paths_report_missing_runtime() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT runtime not linked"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
