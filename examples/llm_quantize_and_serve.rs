//! END-TO-END DRIVER (rust/DESIGN.md §8, validation): proves all layers compose.
//!
//! 1. Train a tiny GPT on the synthetic corpus (logging the loss curve);
//! 2. post-training-quantize it W4A4KV4 (RTN) ± STaMP,
//!    reporting the perplexity gap (the Table-2 effect live);
//! 3. serve batched next-token requests through the L3 coordinator with
//!    FP / quantized / quantized+STaMP variants, reporting latency and
//!    throughput per variant.
//!
//! ```bash
//! cargo run --release --example llm_quantize_and_serve
//! ```

use stamp::baselines::{BaselineKind, QuantHook, QuantStack};
use stamp::config::ServeSpec;
use stamp::coordinator::{Executor, Server};
use stamp::data::Corpus;
use stamp::eval::perplexity;
use stamp::eval::tables::{calibrate_gpt, TableOpts};
use stamp::model::{FpHook, Gpt, GptConfig, LinearHook};
use stamp::stamp::SeqTransformKind;
use stamp::tensor::Tensor;
use stamp::train::{train_gpt, TrainConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    // ---- 1. Train ----
    let corpus = Corpus::generate(40_000, 123);
    let mut gpt = Gpt::new(GptConfig::small(), 22);
    println!("training GPT-small ({} params) on {} corpus tokens", gpt.n_params(), 40_000);
    let tc = TrainConfig { steps: 300, ..Default::default() };
    train_gpt(&mut gpt, &corpus, &tc, 0xfeed, |step, loss| {
        println!("  step {step:>4}  loss {loss:.3}");
    });
    // Give the model the massive-activation channels of real LLMs
    // (function-preserving; same protocol as the Table-2 harness).
    gpt.inject_outlier_channels(4, 30.0);
    let gpt = Arc::new(gpt);

    // ---- 2. Quantize + evaluate ----
    let opts = TableOpts::full();
    let seqs_all = corpus.sequences(opts.seq_len);
    let seqs: Vec<&[u32]> = seqs_all.iter().take(opts.eval_seqs).cloned().collect();
    let stats = calibrate_gpt(&gpt, &corpus, opts.seq_len);

    let mk = |stamp: bool| {
        let mut s = QuantStack::build(
            BaselineKind::Rtn,
            &stats,
            Some(stamp::baselines::ActQuantCfg {
                hp_tokens: opts.hp_tokens,
                ..stamp::baselines::ActQuantCfg::w4a4_per_token()
            }),
            Some(stamp::baselines::WeightQuantCfg::w4_per_channel()),
            Some(stamp::baselines::KvQuantCfg {
                hp_tokens: opts.hp_tokens,
                ..stamp::baselines::KvQuantCfg::kv4()
            }),
            0x5EED,
        );
        if stamp {
            s = s.with_stamp(QuantStack::llm_stamp(SeqTransformKind::HaarDwt));
        }
        s
    };
    let plain = mk(false);
    let stamped = mk(true);

    let ppl_fp = perplexity(&gpt, &FpHook, &seqs);
    let ppl_plain = perplexity(&gpt, &QuantHook::new(&plain), &seqs);
    let ppl_stamp = perplexity(&gpt, &QuantHook::new(&stamped), &seqs);
    println!("\nperplexity (seq {}, 4.125 effective activation bits):", opts.seq_len);
    println!("  FP                 : {ppl_fp:.2}");
    println!("  RTN W4A4KV4        : {ppl_plain:.2}");
    println!("  RTN + STaMP        : {ppl_stamp:.2}");

    // ---- 3. Serve ----
    // Each request carries a token sequence (encoded as f32 tensor row);
    // the executor decodes, runs the hooked forward, returns logits.
    let variants = ["fp", "w4a4", "w4a4+stamp"];
    let gpt_exec = gpt.clone();
    let plain = Arc::new(plain);
    let stamped = Arc::new(stamped);
    let executor: Arc<dyn Executor> = Arc::new(move |variant: &str, inputs: &[&Tensor]| {
        let mut out = Vec::with_capacity(inputs.len());
        for t in inputs {
            let tokens: Vec<u32> = t.data().iter().map(|&v| v as u32).collect();
            let logits = match variant {
                "fp" => gpt_exec.logits_hooked(&FpHook, &tokens),
                "w4a4" => gpt_exec.logits_hooked(&QuantHook::new(&plain), &tokens),
                "w4a4+stamp" => gpt_exec.logits_hooked(&QuantHook::new(&stamped), &tokens),
                other => return Err(format!("unknown variant {other}")),
            };
            out.push(logits);
        }
        Ok(out)
    });

    let spec = ServeSpec { workers: 4, max_batch: 4, max_wait_us: 2_000, queue_depth: 128 };
    let server = Server::start(&spec, &variants, executor);
    let handle = server.handle();

    let n_requests = 48;
    println!("\nserving {n_requests} requests round-robin over {variants:?}…");
    let t0 = Instant::now();
    let mut latencies_ms: Vec<(usize, f64)> = Vec::new();
    let receivers: Vec<(usize, std::sync::mpsc::Receiver<_>, Instant)> = (0..n_requests)
        .map(|i| {
            let variant = variants[i % variants.len()];
            let seq: Vec<f32> =
                seqs[i % seqs.len()].iter().take(64).map(|&t| t as f32).collect();
            let input = Tensor::from_vec(&[1, seq.len()], seq);
            let (_, rx) = handle.submit(variant, input);
            (i % variants.len(), rx, Instant::now())
        })
        .collect();
    for (vi, rx, sent) in &receivers {
        let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        resp.output.expect("ok");
        latencies_ms.push((*vi, sent.elapsed().as_secs_f64() * 1e3));
    }
    let wall = t0.elapsed();
    println!(
        "done: {:.1} req/s total\n\nper-variant mean latency:",
        n_requests as f64 / wall.as_secs_f64()
    );
    for (vi, name) in variants.iter().enumerate() {
        let ls: Vec<f64> =
            latencies_ms.iter().filter(|(v, _)| *v == vi).map(|(_, l)| *l).collect();
        let mean = ls.iter().sum::<f64>() / ls.len() as f64;
        println!("  {name:<12} {mean:>8.1} ms  ({} reqs)", ls.len());
    }
    println!("\ncoordinator metrics:\n{}", handle.metrics.snapshot());
    server.shutdown();
    println!("end-to-end driver complete: train → quantize → eval → serve all green.");
}
