//! LVM scenario: quantized latent-diffusion generation (the paper's
//! Figure 1/6 setting) — run the DiT sampler under W4A4 stacks with and
//! without STaMP's 2-D DWT and report latent/image fidelity per prompt.
//!
//! ```bash
//! cargo run --release --example lvm_generation
//! ```

use stamp::baselines::{ActQuantCfg, BaselineKind, QuantHook, QuantStack, WeightQuantCfg};
use stamp::data::PromptSet;
use stamp::eval::lvm::{decode_latent, image_reward_proxy};
use stamp::eval::tables::calibrate_dit;
use stamp::model::{Dit, DitConfig, FpHook};
use stamp::quant::Granularity;
use stamp::stats::sqnr;

fn main() {
    let dit = Dit::new(DitConfig { steps: 6, ..DitConfig::pixart() }, 0xD17);
    println!(
        "DiT (PixArt-Σ analogue): {} params, {}x{} latent grid, {} denoise steps",
        dit.n_params(),
        dit.cfg.grid_h,
        dit.cfg.grid_w,
        dit.cfg.steps
    );
    let stats = calibrate_dit(&dit);

    let mk = |kind: BaselineKind, stamp: bool| {
        let act = ActQuantCfg {
            bits: 4,
            hp_tokens: 16,
            hp_bits: 8,
            granularity: Granularity::PerBlock { block: 64 },
            range_shrink: 1.0,
        };
        let mut s = QuantStack::build(
            kind,
            &stats,
            Some(act),
            Some(WeightQuantCfg::w4_block64()),
            None,
            0x5EED,
        )
        .with_lvm_skips();
        if stamp {
            s = s.with_stamp(QuantStack::lvm_stamp(dit.cfg.grid_h, dit.cfg.grid_w));
        }
        s
    };

    let prompts = PromptSet::coco();
    println!("\n{:<44} {:>10} {:>10} {:>8}", "prompt", "RTN dB", "+STaMP dB", "IR gain");
    for prompt in prompts.prompts.iter().take(6) {
        let z_fp = dit.sample(&FpHook, prompt, 1);
        let stacks = (mk(BaselineKind::Rtn, false), mk(BaselineKind::Rtn, true));
        let z_plain = dit.sample(&QuantHook::new(&stacks.0), prompt, 1);
        let z_stamp = dit.sample(&QuantHook::new(&stacks.1), prompt, 1);
        let img_fp = decode_latent(&dit, &z_fp);
        let s_plain = sqnr(&img_fp, &decode_latent(&dit, &z_plain));
        let s_stamp = sqnr(&img_fp, &decode_latent(&dit, &z_stamp));
        let short: String = prompt.chars().take(42).collect();
        println!(
            "{:<44} {:>10.2} {:>10.2} {:>+8.2}",
            short,
            s_plain,
            s_stamp,
            image_reward_proxy(s_stamp) - image_reward_proxy(s_plain)
        );
    }
    println!("\n(2-D Haar DWT over the 16x16 token grid; 64-block W4A4 as in Table 1)");
}
