//! PJRT serving: load the AOT artifacts (`make artifacts`) and serve them
//! through the coordinator — the full three-layer path with Python absent
//! at request time.
//!
//! The `xla` crate's PJRT client is not `Send` (it wraps an `Rc` device
//! handle), so a dedicated **device-owner thread** owns the engine and all
//! compiled executables; coordinator workers forward work to it over a
//! channel. This mirrors production single-device serving layouts.
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example pjrt_serving
//! ```
//!
//! Requires the `pjrt` cargo feature (this example is gated behind
//! `required-features` in `rust/Cargo.toml`); the default build serves the
//! same coordinator path through `stamp::runtime::NativeExecutor` instead.

use stamp::config::ServeSpec;
use stamp::coordinator::{Executor, Server};
use stamp::runtime::{ArtifactRegistry, Engine};
use stamp::tensor::Tensor;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Work item sent to the device-owner thread.
struct DeviceJob {
    variant: String,
    input: Tensor,
    reply: mpsc::Sender<Result<Tensor, String>>,
}

fn main() -> stamp::error::Result<()> {
    let dir = std::env::var("STAMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let reg = match ArtifactRegistry::load(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("no artifacts ({e}); run `make artifacts` first");
            return Ok(());
        }
    };
    let entries: Vec<_> = reg.entries().to_vec();
    let variants: Vec<String> = entries.iter().map(|e| e.name.clone()).collect();
    let input_shapes: HashMap<String, Vec<Vec<usize>>> =
        entries.iter().map(|e| (e.name.clone(), e.input_shapes())).collect();

    // ---- device-owner thread: engine + executables live here ----
    let (job_tx, job_rx) = mpsc::channel::<DeviceJob>();
    let paths: Vec<(String, std::path::PathBuf, Vec<Vec<usize>>)> = entries
        .iter()
        .map(|e| (e.name.clone(), reg.path_for(e), e.input_shapes()))
        .collect();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<String, String>>();
    let device_thread = std::thread::Builder::new()
        .name("pjrt-device-owner".into())
        .spawn(move || {
            let engine = match Engine::cpu() {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let mut exes = HashMap::new();
            for (name, path, _) in &paths {
                let t0 = Instant::now();
                match engine.load(path) {
                    Ok(exe) => {
                        let _ = ready_tx
                            .send(Ok(format!("  {:<16} compiled in {:.0?}", name, t0.elapsed())));
                        exes.insert(name.clone(), exe);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{name}: {e}")));
                        return;
                    }
                }
            }
            let _ = ready_tx.send(Ok("__ready__".into()));
            let shape_of: HashMap<String, Vec<Vec<usize>>> =
                paths.iter().map(|(n, _, s)| (n.clone(), s.clone())).collect();
            while let Ok(job) = job_rx.recv() {
                let result = (|| {
                    let exe = exes
                        .get(&job.variant)
                        .ok_or_else(|| format!("no executable {}", job.variant))?;
                    let sig = &shape_of[&job.variant];
                    let mut args: Vec<Tensor> = vec![job.input.clone()];
                    // Extra (weight) inputs beyond the request tensor are
                    // deterministic small-random fills for the demo.
                    for extra in sig.iter().skip(1) {
                        args.push(Tensor::randn(extra, 7).scale(0.05));
                    }
                    let mut res = engine.run(exe, &args).map_err(|e| e.to_string())?;
                    Ok(res.remove(0))
                })();
                let _ = job.reply.send(result);
            }
        })
        .expect("spawn device thread");

    println!("compiling {} artifacts on the device-owner thread…", variants.len());
    loop {
        match ready_rx.recv().map_err(|e| stamp::err!("device thread died: {e}"))? {
            Ok(msg) if msg == "__ready__" => break,
            Ok(msg) => println!("{msg}"),
            Err(e) => stamp::bail!("artifact load failed: {e}"),
        }
    }

    // ---- coordinator: executor forwards to the device thread ----
    let job_tx = Arc::new(Mutex::new(job_tx));
    let executor: Arc<dyn Executor> = Arc::new(move |variant: &str, inputs: &[&Tensor]| {
        let mut replies = Vec::with_capacity(inputs.len());
        {
            let tx = job_tx.lock().unwrap();
            for t in inputs {
                let (rtx, rrx) = mpsc::channel();
                tx.send(DeviceJob { variant: variant.to_string(), input: (*t).clone(), reply: rtx })
                    .map_err(|e| format!("device thread gone: {e}"))?;
                replies.push(rrx);
            }
        }
        replies
            .into_iter()
            .map(|rrx| rrx.recv().map_err(|e| format!("device reply lost: {e}"))?)
            .collect()
    });

    let name_refs: Vec<&str> = variants.iter().map(|s| s.as_str()).collect();
    let spec = ServeSpec { workers: 2, max_batch: 4, max_wait_us: 1_000, queue_depth: 64 };
    let server = Server::start(&spec, &name_refs, executor);
    let handle = server.handle();

    let n = 24usize;
    println!("\nserving {n} requests round-robin over {variants:?}…");
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let v = &variants[i % variants.len()];
            let shape = &input_shapes[v][0];
            handle.submit(v, Tensor::randn(shape, i as u64).scale(0.3)).1
        })
        .collect();
    let mut ok = 0;
    for rx in &rxs {
        let resp = rx.recv_timeout(Duration::from_secs(300)).expect("response");
        match resp.output {
            Ok(t) => {
                assert!(t.all_finite());
                ok += 1;
            }
            Err(e) => eprintln!("request failed: {e}"),
        }
    }
    let wall = t0.elapsed();
    println!("{ok}/{n} ok in {wall:.2?} ({:.1} req/s)", n as f64 / wall.as_secs_f64());
    println!("\nmetrics:\n{}", handle.metrics.snapshot());
    server.shutdown();
    drop(device_thread);
    Ok(())
}
