//! Bit-allocation study (the §3.3/Appendix-A.2 machinery in isolation):
//! given the energy spectrum of transformed activations, compare the
//! uniform, continuous-optimal, and hardware-friendly 2-level allocations,
//! and show where the 2-level scheme's knee sits (Figure 4 narrative).
//!
//! ```bash
//! cargo run --release --example bit_allocation_study
//! ```

use stamp::data::{ActivationGenerator, ActivationSpec};
use stamp::eval::figures;
use stamp::quant::{optimal_bits, quantization_error, BitAllocation, Granularity};
use stamp::transforms::{HaarDwt, SequenceTransform};

fn main() {
    let s = 256;
    let gen = ActivationGenerator::new(ActivationSpec {
        outlier_channels: 0,
        sink_scale: 0.0,
        ..ActivationSpec::llm(s, 64)
    });
    let samples = gen.calibration_set(12, 9);

    // Energy spectrum after the DWT.
    let dwt = HaarDwt::new(s, 3);
    let mut energies = vec![0.0f64; s];
    for x in &samples {
        let y = dwt.forward(x);
        for (e, v) in energies.iter_mut().zip(stamp::stats::token_energies(&y)) {
            *e += v;
        }
    }

    println!("== allocation objectives at avg 5 bits (lower is better) ==");
    let c = figures::fig4a_allocations(&energies, 5.0, 32);
    println!("uniform            : {:.4}", c.uniform_objective);
    println!("2-level (8b x 32)  : {:.4}", c.two_level_objective);
    println!("continuous optimal : {:.4}", c.optimal_objective);

    // Continuous-optimal widths for the top tokens.
    let e32: Vec<f32> = energies.iter().map(|&e| e as f32).collect();
    let b = optimal_bits(&e32, 5.0 * s as f64);
    println!("\noptimal b*_i for the first 8 transformed tokens (b̄=5):");
    for (i, bi) in b.iter().take(8).enumerate() {
        println!("  token {i}: {bi:.2} bits (energy {:.1})", energies[i]);
    }

    // Measured error as hp-token count varies at fixed avg bits ≈ 4.25.
    println!("\n== measured quantization error vs hp-token count (lp=4) ==");
    let x = &samples[0];
    for hp in [0usize, 4, 8, 16, 32, 64] {
        let alloc = BitAllocation::two_level(hp, 8, 4);
        let err = quantization_error(x, &dwt, &alloc, Granularity::PerToken);
        println!(
            "  hp={hp:<3} avg {:.3} bits  error {err:10.4}",
            alloc.average_bits(s)
        );
    }
    println!("\nNote the sharp drop once the high-energy DWT approximation");
    println!("coefficients (first s/2^levels tokens) are covered — Figure 4b's knee.");
}
