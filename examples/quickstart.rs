//! Quickstart: quantize one activation matrix with STaMP and compare
//! against uniform quantization at the same average bit width.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use stamp::data::{ActivationGenerator, ActivationSpec};
use stamp::prelude::*;

fn main() {
    // Locally-correlated "LLM layer" activations (AR(1) ρ=0.95, outlier
    // channels, massive first token) — the regime the paper targets.
    let s = 256;
    let d = 128;
    let gen = ActivationGenerator::new(ActivationSpec::llm(s, d));
    let x = gen.sample(42);

    // Uniform 4-bit per-token quantization (the "before" column).
    let uniform = Stamp::new(
        StampConfig {
            transform: SeqTransformKind::Identity,
            hp_tokens: 0,
            lp_bits: 4,
            ..Default::default()
        },
        s,
    );

    // STaMP: Haar DWT along the sequence + {8-bit × 8 tokens, 4-bit rest}
    // (8/256 ≡ the paper's 64/2048 = 4.125 average bits), skipping the
    // attention-sink token (§B.2).
    let stamp = Stamp::new(
        StampConfig { hp_tokens: 8, skip_first_token: true, ..Default::default() },
        s,
    );

    let q_uniform = uniform.quantize_dequantize(&x);
    let q_stamp = stamp.quantize_dequantize(&x);

    println!("input: {s}x{d} AR(1) activations with outliers + sink token");
    println!(
        "uniform 4-bit       : avg bits {:.3}  SQNR {:>6.2} dB",
        uniform.average_bits(d),
        sqnr(&x, &q_uniform)
    );
    println!(
        "STaMP (dwt, 8 hp)   : avg bits {:.3}  SQNR {:>6.2} dB",
        stamp.average_bits(d),
        sqnr(&x, &q_stamp)
    );
    println!(
        "transform overhead  : {} FLOPs per application (O(s·d))",
        stamp.transform_flops(d) / 2
    );

    // The fused quantized linear layer (Figure 2a).
    let w = Tensor::randn(&[d, 64], 7).scale(0.1);
    let y_fp = x.matmul(&w);
    let layer = stamp::stamp::StampLinear::new(
        Stamp::new(StampConfig { hp_tokens: 8, ..Default::default() }, s),
        w,
        None,
        Box::new(stamp::transforms::HadamardFeature::new(d, 3)),
    );
    let y_q = layer.forward(&x);
    println!("STaMP linear layer  : output SQNR {:.2} dB vs FP", sqnr(&y_fp, &y_q));
}
