//! Autoregressive generation with the STaMP-aware quantized KV cache:
//! train the tiny GPT briefly, greedy-decode 64 tokens under (a) the fp32
//! reference cache and (b) the packed two-level cache, and compare
//! tokens/sec plus the cache's physical storage footprint.
//!
//! ```bash
//! cargo run --release --example generate
//! ```

use stamp::model::FpHook;
use stamp::obs::{EngineObs, TraceEvent, TraceKind};
use stamp::prelude::*;
use std::time::Instant;

fn main() {
    // A briefly-trained tiny GPT (same builder the eval harnesses use).
    let (gpt, corpus) = stamp::train::build_trained_model("tiny", 40);
    let gpt = std::sync::Arc::new(gpt);
    let seqs = corpus.sequences(32);
    let prompt: Vec<u32> = seqs[0][..16].to_vec();
    let n_new = 64usize;

    // (a) fp32 reference cache — decode here is bit-identical to the
    // full-sequence forward (tests/decode.rs parity harness).
    let t0 = Instant::now();
    let mut fp_cache = KvCache::fp32(gpt.cfg.n_layers);
    let fp_tokens = gpt.generate_greedy(&FpHook, &prompt, n_new, &mut fp_cache);
    let fp_dt = t0.elapsed();

    // (b) packed two-level cache: 8 sink tokens at 8 bits, KV4 steady
    // state, 16-token blocks passed through a Haar DWT before packing.
    let kv = KvCacheConfig::two_level(8, 8, 4, 16).with_transform(SeqTransformKind::HaarDwt);
    let t0 = Instant::now();
    let mut q_cache = KvCache::new(gpt.cfg.n_layers, kv);
    let q_tokens = gpt.generate_greedy(&FpHook, &prompt, n_new, &mut q_cache);
    let q_dt = t0.elapsed();

    println!("prompt : {:?}…", &prompt[..8]);
    println!("fp32   : {}", corpus.tokenizer.decode(&fp_tokens[..16.min(fp_tokens.len())]));
    println!("packed : {}", corpus.tokenizer.decode(&q_tokens[..16.min(q_tokens.len())]));
    let same = fp_tokens.iter().zip(&q_tokens).filter(|(a, b)| a == b).count();
    println!("token agreement: {same}/{n_new}");

    println!(
        "\nfp32 cache   : {:>8.1} tok/s   {:>9} bits stored ({:.2} bits/elem)",
        n_new as f64 / fp_dt.as_secs_f64(),
        fp_cache.storage_bits(),
        fp_cache.average_storage_bits(),
    );
    println!(
        "packed cache : {:>8.1} tok/s   {:>9} bits stored ({:.2} bits/elem)",
        n_new as f64 / q_dt.as_secs_f64(),
        q_cache.storage_bits(),
        q_cache.average_storage_bits(),
    );
    // storage_bits is what a deployment *ships/stores* (packed codes +
    // scale parameters, Appendix-C accounting). This pure-Rust decode
    // additionally keeps an fp32 working view of flushed blocks so
    // attention reads are copies, not re-dequantization — see
    // rust/DESIGN.md §11; a fused kernel would consume the packed blocks
    // directly.
    println!(
        "stored footprint: {:.1}× smaller than fp32 (packed codes + scales)",
        fp_cache.storage_bits() as f64 / q_cache.storage_bits() as f64
    );

    // Batched decode (PR 4): the same four prompts as four concurrent
    // streams through one step-synchronized DecodeEngine run — every
    // linear runs once per step over the fused [n_active × d_model]
    // activation instead of once per stream. With the fp32 cache each
    // stream is bit-identical to its serial run (tests/decode.rs), so
    // the only difference is wall time.
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest { prompt: seqs[i][..8 + 4 * i].to_vec(), n_new })
        .collect();
    let t0 = Instant::now();
    let serial: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| {
            let mut c = KvCache::fp32(gpt.cfg.n_layers);
            gpt.generate_greedy(&FpHook, &r.prompt, r.n_new, &mut c)
        })
        .collect();
    let serial_dt = t0.elapsed();
    let mut engine = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy);
    let t0 = Instant::now();
    let batched = engine.run_fp(&reqs).expect("engine run");
    let batched_dt = t0.elapsed();
    let agree = serial.iter().zip(&batched).all(|(s, b)| s == &b.tokens);
    println!(
        "\nbatched decode (4 streams): serial {:>7.1} tok/s/stream, fused {:>7.1} tok/s/stream ({:.2}× — bit-identical: {agree})",
        (4 * n_new) as f64 / serial_dt.as_secs_f64() / 4.0,
        (4 * n_new) as f64 / batched_dt.as_secs_f64() / 4.0,
        serial_dt.as_secs_f64() / batched_dt.as_secs_f64(),
    );
    assert!(agree, "fp32-cache batched decode must match serial decode");

    // Structured tracing (PR 8): the same four streams through an engine
    // with a trace ring attached (the `[observability]` TOML knobs route
    // to exactly this). The drained JSONL reconstructs each stream's
    // timeline — Admit → PrefillChunk… → one DecodeStep per generated
    // token → Retire — and the always-on TTFT/TPOT histograms summarize
    // the same timestamps. CI greps the "trace: drained" line.
    let mut traced = DecodeEngine::new(gpt.clone(), KvCacheConfig::fp32(), Sampling::Greedy)
        .with_obs(std::sync::Arc::new(EngineObs::with_trace(4096)));
    traced.run_fp(&reqs).expect("traced engine run");
    let obs = traced.obs().clone();
    let jsonl = obs.drain_jsonl("gen");
    let events: Vec<TraceEvent> = jsonl
        .lines()
        .map(|l| TraceEvent::from_json(l).expect("every drained JSONL line parses"))
        .collect();
    for i in 0..reqs.len() {
        let evs: Vec<&TraceEvent> = events.iter().filter(|e| e.stream == i as u64).collect();
        assert_eq!(evs.first().expect("stream admitted").kind, TraceKind::Admit);
        assert_eq!(evs.last().expect("stream retired").kind, TraceKind::Retire);
        let steps = evs.iter().filter(|e| e.kind == TraceKind::DecodeStep).count();
        assert_eq!(steps, n_new, "stream {i}: one DecodeStep per generated token");
    }
    println!(
        "\ntrace: drained {} events across {} streams (p50 TTFT {} µs, p50 TPOT {} µs, {} overwritten)",
        events.len(),
        reqs.len(),
        obs.ttft_us.quantile(0.5),
        obs.tpot_us.quantile(0.5),
        obs.trace_dropped(),
    );
    println!("trace sample: {}", jsonl.lines().next().unwrap_or(""));
}
